"""Kernel roofline placement — paper Fig. 9-13.

Places every L2 problem's four backends on the v5e roofline (arithmetic
intensity vs achieved TFLOPS under original FLOP accounting), reproducing the
paper's two-regime observation: compute-bound GEMM/MatMul near the ceiling
(restructured kernels above it), bandwidth-bound conv families pinned to the
bandwidth slope."""

from __future__ import annotations

from repro.aibench import build_program, load_specs
from repro.forge import Forge, ForgeConfig
from repro.hw.specs import TPU_V5E
from repro.ir.cost import CostModel


def run(max_problems=None):
    print("\n== Kernel rooflines (paper Fig. 9-13) ==")
    cm = CostModel(TPU_V5E)
    forge = Forge(ForgeConfig())
    peak = TPU_V5E.peak_flops_bf16 / 1e12
    knee = TPU_V5E.peak_flops_bf16 / TPU_V5E.hbm_bw
    print(f"v5e: {peak:.0f} TFLOPS bf16 ceiling, {TPU_V5E.hbm_bw/1e9:.0f} GB/s "
          f"slope, knee at AI={knee:.0f} FLOP/B")
    print(f"{'kernel':28s} {'AI':>7s} {'eager':>8s} {'compile':>8s} "
          f"{'opt':>8s} {'regime':>9s} {'>ceiling':>8s}")
    rows = []
    for spec in load_specs()[:max_problems]:
        eager = build_program(spec.builder, spec.dims("bench"), "eager",
                              meta=spec.meta)
        compiled = build_program(spec.builder, spec.dims("bench"), "compiled",
                                 meta=spec.meta)
        res = forge.optimize_program(
            spec.name,
            build_program(spec.builder, spec.dims("ci"), "naive", meta=spec.meta),
            build_program(spec.builder, spec.dims("bench"), "naive", meta=spec.meta),
            tags=tuple(spec.tags), target_dtype=spec.target_dtype,
            rtol=spec.rtol, atol=spec.atol, meta=spec.meta).result.result
        ce = cm.program_cost(eager)
        cc = cm.program_cost(compiled)
        co = cm.program_cost(res.bench_program)
        ai = co.original_flops / max(co.hbm_bytes, 1)
        regime = "compute" if ai > knee else "memory"
        above = co.tflops_effective > peak
        print(f"{spec.name:28s} {ai:7.0f} {ce.tflops_effective:8.1f} "
              f"{cc.tflops_effective:8.1f} {co.tflops_effective:8.1f} "
              f"{regime:>9s} {'YES' if above else '':>8s}")
        rows.append({"name": spec.name, "family": spec.family,
                     "ai": ai, "tflops_opt": co.tflops_effective,
                     "regime": regime, "above_ceiling": above})
    above = [r["name"] for r in rows if r["above_ceiling"]]
    print(f"\nkernels above the roofline ceiling (restructuring under original "
          f"accounting, paper Fig. 9): {above}")
    return rows


if __name__ == "__main__":
    run()
