"""§Perf hillclimb driver: run a (cell, variant) dry-run in a subprocess and
report the three roofline terms vs. the baseline.

    PYTHONPATH=src python -m benchmarks.perf_iterate --arch granite-moe-3b-a800m \
        --shape train_4k --mesh single --variant no_fsdp,mb=1

Variants (env-driven, see launch/dryrun.py):
    no_fsdp          REPRO_NO_FSDP=1      no ZeRO weight sharding (replicate over data)
    no_sp            REPRO_NO_SP=1        no sequence-parallel residual hints
    no_remat         flags.remat=False
    mb=N             gradient-accumulation microbatches
    loss_chunks=N    streamed-CE chunk count
    kvq=int8         int8 KV cache (decode cells)
Results append to results/perf_iterations.json.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.roofline.analyze import from_record


def run_variant(arch: str, shape: str, mesh: str, variant: str,
                timeout: int = 2400) -> dict:
    out = pathlib.Path(f"results/perf/{arch}.{shape}.{mesh}."
                       f"{variant.replace(',', '+').replace('=', '') or 'baseline'}.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_VARIANT"] = variant
    if "no_fsdp" in variant:
        env["REPRO_NO_FSDP"] = "1"
    if "no_sp" in variant:
        env["REPRO_NO_SP"] = "1"
    if "no_moe_tp" in variant:
        env["REPRO_NO_MOE_TP"] = "1"
    if "repl_unembed" in variant:
        env["REPRO_REPLICATE_UNEMBED"] = "1"
    for tok in variant.split(","):
        if tok.startswith("attn_chunk="):
            env["REPRO_ATTN_CHUNK"] = tok.split("=")[1]
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(out)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                          env=env)
    if not out.exists():
        raise RuntimeError((proc.stderr or proc.stdout)[-2000:])
    return json.loads(out.read_text())


def report(rec: dict, base: dict = None) -> str:
    t = from_record(rec)
    line = (f"comp={t.t_compute:8.3f}s mem={t.t_memory:8.3f}s "
            f"coll={t.t_collective:8.3f}s dom={t.dominant:10s} "
            f"rf={100*t.roofline_fraction:6.2f}% "
            f"peak={rec['memory']['peak_projected_tpu']/2**30:5.1f}GiB "
            f"fits={rec.get('fits_hbm')}")
    if base is not None:
        tb = from_record(base)
        dom = tb.dominant
        attr = {"compute": ("t_compute",), "memory": ("t_memory",),
                "collective": ("t_collective",)}[dom][0]
        before, after = getattr(tb, attr), getattr(t, attr)
        line += (f"  | dominant({dom}): {before:.3f}s -> {after:.3f}s "
                 f"({before/max(after,1e-12):.2f}x)")
    return line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="")
    ap.add_argument("--baseline", default=None,
                    help="path to baseline cell JSON for delta reporting")
    args = ap.parse_args()
    base = None
    bp = args.baseline or f"results/dryrun/cells/{args.arch}.{args.shape}.{args.mesh}.json"
    if pathlib.Path(bp).exists():
        base = json.loads(pathlib.Path(bp).read_text())
    rec = run_variant(args.arch, args.shape, args.mesh, args.variant)
    tag = args.variant or "baseline"
    print(f"[{args.arch} {args.shape} {args.mesh}] {tag}")
    print("  " + report(rec, base if args.variant else None))
    log = pathlib.Path("results/perf_iterations.json")
    hist = json.loads(log.read_text()) if log.exists() else []
    hist.append({"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                 "variant": tag, "record": {k: rec[k] for k in
                                            ("cost", "collectives", "memory",
                                             "model_flops", "n_devices")
                                            if k in rec}})
    log.write_text(json.dumps(hist, indent=1))


if __name__ == "__main__":
    main()
