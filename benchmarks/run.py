"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only l2|fa|roofline|ablations|dryrun]

Prints per-kernel tables and a ``name,us_per_call,derived`` CSV summary.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["l2", "fa", "roofline", "ablations", "dryrun"])
    args = ap.parse_args()
    csv_rows = []

    if args.only in (None, "l2"):
        from benchmarks.kernelbench_l2 import run as run_l2
        summary = run_l2()
        for r in summary.results:
            csv_rows.append((r.name, r.optimized_us,
                             f"x{r.speedup_vs_eager:.2f}_vs_eager"))

    if args.only in (None, "fa"):
        from benchmarks.flash_attention import run as run_fa
        fa = run_fa(csv_rows=csv_rows)

    if args.only in (None, "roofline"):
        from benchmarks.kernel_roofline import run as run_rl
        run_rl(max_problems=12 if args.only is None else None)

    if args.only in (None, "ablations"):
        from benchmarks.ablations import run as run_ab
        run_ab()

    if args.only == "dryrun":
        from repro.roofline.report import print_report
        print_report(pathlib.Path("results/dryrun/all.json"))

    print("\n== CSV summary (name,us_per_call,derived) ==")
    for name, us, derived in csv_rows:
        if isinstance(us, tuple):
            name, us, derived = us
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
