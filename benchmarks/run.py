"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only l2|fa|roofline|ablations|dryrun]
                                            [--workers N] [--l2-runs N]

Prints per-kernel tables and a ``name,us_per_call,derived`` CSV summary.
``--only l2`` additionally writes the machine-readable ``BENCH_l2.json``
artifact (per-kernel ``us_per_call``, speedups, cache hit/miss counts,
geomeans) so the perf trajectory is trackable across PRs.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def _l2_artifact(summary) -> dict:
    stats = summary.engine_stats
    return {
        "suite": "kernelbench_l2",
        "kernels": [
            {
                "name": r.name,
                "family": r.family,
                "us_per_call": r.optimized_us,
                "eager_us": r.eager_us,
                "compiled_us": r.compiled_us,
                "naive_us": r.naive_us,
                "speedup_vs_eager": r.speedup_vs_eager,
                "speedup_vs_best_baseline": r.speedup_vs_best_baseline,
                "speedup_vs_naive": r.speedup_vs_naive,
                "tflops_optimized": r.tflops_optimized,
                "correct": r.correct,
                "cache_hit": r.cache_hit,
            }
            for r in summary.results
        ],
        "aggregates": {
            "geomean_vs_eager": summary.geomean_vs_eager,
            "geomean_vs_best": summary.geomean_vs_best,
            "pct_improved": summary.pct_improved,
            "over_5x": len(summary.over_5x),
            "all_correct": summary.all_correct,
        },
        "engine": stats.as_dict() if stats else {},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["l2", "fa", "roofline", "ablations", "dryrun"])
    ap.add_argument("--workers", type=int, default=1,
                    help="engine worker threads for the l2 suite")
    ap.add_argument("--l2-runs", type=int, default=1,
                    help="suite passes through the engine (2 exercises the "
                         "result cache)")
    ap.add_argument("--l2-json", default="BENCH_l2.json",
                    help="path of the l2 artifact (written for --only l2)")
    args = ap.parse_args()
    csv_rows = []

    if args.only in (None, "l2"):
        from benchmarks.kernelbench_l2 import run as run_l2
        summary = run_l2(workers=args.workers, runs=args.l2_runs)
        for r in summary.results:
            csv_rows.append((r.name, r.optimized_us,
                             f"x{r.speedup_vs_eager:.2f}_vs_eager"))
        out = pathlib.Path(args.l2_json)
        out.write_text(json.dumps(_l2_artifact(summary), indent=2))
        print(f"\nwrote {out}")

    if args.only in (None, "fa"):
        from benchmarks.flash_attention import run as run_fa
        fa = run_fa(csv_rows=csv_rows)

    if args.only in (None, "roofline"):
        from benchmarks.kernel_roofline import run as run_rl
        run_rl(max_problems=12 if args.only is None else None)

    if args.only in (None, "ablations"):
        from benchmarks.ablations import run as run_ab
        run_ab()

    if args.only == "dryrun":
        from repro.roofline.report import print_report
        print_report(pathlib.Path("results/dryrun/all.json"))

    print("\n== CSV summary (name,us_per_call,derived) ==")
    for name, us, derived in csv_rows:
        if isinstance(us, tuple):
            name, us, derived = us
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
