"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only l2|fa|roofline|ablations|dryrun]
                                            [--workers N] [--backend serial|thread|process]
                                            [--l2-runs N] [--cache store.json]
                                            [--baseline BENCH_l2.json]

Prints per-kernel tables and a ``name,us_per_call,derived`` CSV summary.
``--only l2`` additionally writes the machine-readable ``BENCH_l2.json``
artifact (per-kernel ``us_per_call``, speedups, cache/transfer counts,
geomeans) so the perf trajectory is trackable across PRs.

``--baseline`` is the regression gate: the previous artifact is loaded
*before* the run (so the same path can serve as both baseline and output),
per-kernel ``us_per_call`` is diffed against it, and the process exits
non-zero if any kernel regressed by more than ``--regression-threshold``
(default 5%). ``scripts/ci.sh`` wires this in whenever a baseline artifact
exists.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

REGRESSION_THRESHOLD = 0.05


def diff_against_baseline(artifact: dict, baseline: dict,
                          threshold: float = REGRESSION_THRESHOLD) -> dict:
    """Per-kernel ``us_per_call`` diff of a fresh l2 artifact against a
    previous one. Returns ``{"regressions": [...], "improvements": [...],
    "new": [...], "removed": [...]}`` where each regression/improvement row
    is ``(name, baseline_us, new_us, ratio)``. A kernel regresses when its
    time grows by more than ``threshold`` (relative)."""
    base = {k["name"]: float(k["us_per_call"])
            for k in baseline.get("kernels", [])}
    seen = set()
    regressions, improvements, new = [], [], []
    for k in artifact.get("kernels", []):
        name, us = k["name"], float(k["us_per_call"])
        if name not in base:
            new.append(name)
            continue
        seen.add(name)
        # a degenerate 0us baseline can never be beaten fairly: any real
        # time must count as a regression, not be masked by a ratio of 1
        ratio = (us / base[name] if base[name] > 0
                 else float("inf") if us > 0 else 1.0)
        if ratio > 1.0 + threshold:
            regressions.append((name, base[name], us, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, base[name], us, ratio))
    removed = sorted(set(base) - seen)
    return {"regressions": regressions, "improvements": improvements,
            "new": new, "removed": removed}


def print_baseline_report(diff: dict, threshold: float) -> None:
    print(f"\n== baseline diff (>{threshold:.0%} = regression) ==")
    for name, b, n, r in diff["improvements"]:
        speedup = f"{1/r:.2f}x faster" if r > 0 else "now ~0us"
        print(f"  IMPROVED  {name:28s} {b:10.2f}us -> {n:10.2f}us "
              f"({speedup})")
    for name in diff["new"]:
        print(f"  NEW       {name}")
    for name in diff["removed"]:
        print(f"  REMOVED   {name} (lost coverage fails the gate)")
    for name, b, n, r in diff["regressions"]:
        print(f"  REGRESSED {name:28s} {b:10.2f}us -> {n:10.2f}us "
              f"({r:.2f}x slower)")
    if not diff["regressions"] and not diff["removed"]:
        print("  no regressions")


def _l2_artifact(summary) -> dict:
    stats = summary.engine_stats
    return {
        "suite": "kernelbench_l2",
        "kernels": [
            {
                "name": r.name,
                "family": r.family,
                "us_per_call": r.optimized_us,
                "eager_us": r.eager_us,
                "compiled_us": r.compiled_us,
                "naive_us": r.naive_us,
                "speedup_vs_eager": r.speedup_vs_eager,
                "speedup_vs_best_baseline": r.speedup_vs_best_baseline,
                "speedup_vs_naive": r.speedup_vs_naive,
                "tflops_optimized": r.tflops_optimized,
                "correct": r.correct,
                "cache_hit": r.cache_hit,
                "transfer": r.transfer,
            }
            for r in summary.results
        ],
        "aggregates": {
            "geomean_vs_eager": summary.geomean_vs_eager,
            "geomean_vs_best": summary.geomean_vs_best,
            "pct_improved": summary.pct_improved,
            "over_5x": len(summary.over_5x),
            "all_correct": summary.all_correct,
        },
        "engine": stats.as_dict() if stats else {},
        # verify-layer counters ride alongside engine stats (separate
        # because shared-cache hit counts are backend-dependent)
        "verify": (summary.verify_stats.as_dict()
                   if getattr(summary, "verify_stats", None) else {}),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["l2", "fa", "roofline", "ablations", "dryrun"])
    ap.add_argument("--workers", type=int, default=1,
                    help="engine workers for the l2 suite")
    ap.add_argument("--backend", default="thread",
                    choices=["serial", "thread", "process"],
                    help="execution backend for the l2 suite (process = "
                         "spawned worker processes; see ForgeConfig."
                         "execution_backend)")
    ap.add_argument("--cache", default=None,
                    help="result-store path for the l2 suite; point it at "
                         "a warm store (scripts/warm_store.py) so cold CI "
                         "runs start from replay/transfer seeds")
    ap.add_argument("--l2-runs", type=int, default=1,
                    help="suite passes through the engine (2 exercises the "
                         "result cache)")
    ap.add_argument("--l2-json", default="BENCH_l2.json",
                    help="path of the l2 artifact (written for --only l2)")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_l2.json to diff against; exit "
                         "non-zero on per-kernel regressions")
    ap.add_argument("--regression-threshold", type=float,
                    default=REGRESSION_THRESHOLD,
                    help="relative us_per_call growth that counts as a "
                         "regression (default 0.05)")
    args = ap.parse_args()
    if args.baseline and args.only not in (None, "l2"):
        ap.error(f"--baseline gates the l2 suite; it does nothing with "
                 f"--only {args.only}")
    csv_rows = []
    regressions = []

    if args.only in (None, "l2"):
        # load the baseline before running: the artifact path may be the
        # same file we are about to overwrite
        baseline = None
        baseline_path = None
        if args.baseline:
            bp = pathlib.Path(args.baseline)
            if bp.exists():
                try:
                    baseline = json.loads(bp.read_text())
                    baseline_path = bp.resolve()
                except json.JSONDecodeError as e:
                    # a torn artifact (killed run) must not wedge CI forever
                    print(f"baseline {bp} is corrupt ({e}); "
                          f"skipping regression gate")
            else:
                print(f"baseline {bp} not found; skipping regression gate")
        from benchmarks.kernelbench_l2 import run as run_l2
        from repro.forge import ForgeConfig
        summary = run_l2(config=ForgeConfig(workers=args.workers,
                                            execution_backend=args.backend,
                                            cache_path=args.cache),
                         runs=args.l2_runs)
        for r in summary.results:
            csv_rows.append((r.name, r.optimized_us,
                             f"x{r.speedup_vs_eager:.2f}_vs_eager"))
        artifact = _l2_artifact(summary)
        out = pathlib.Path(args.l2_json)
        if baseline is not None:
            diff = diff_against_baseline(artifact, baseline,
                                         args.regression_threshold)
            print_baseline_report(diff, args.regression_threshold)
            # removed kernels are lost coverage, not a pass
            regressions = diff["regressions"] + [
                (name, None, None, None) for name in diff["removed"]]
        if regressions and out.resolve() == baseline_path:
            # never ratchet the baseline down: a failing run must not
            # overwrite the artifact it failed against, or a simple re-run
            # would accept the regression
            print(f"\nNOT writing {out} (gate failed against it)")
        else:
            # atomic write: a killed run must not leave a torn artifact
            # for the next gate to choke on
            tmp = out.with_name(out.name + ".tmp")
            tmp.write_text(json.dumps(artifact, indent=2))
            tmp.replace(out)
            print(f"\nwrote {out}")

    if args.only in (None, "fa"):
        from benchmarks.flash_attention import run as run_fa
        fa = run_fa(csv_rows=csv_rows)

    if args.only in (None, "roofline"):
        from benchmarks.kernel_roofline import run as run_rl
        run_rl(max_problems=12 if args.only is None else None)

    if args.only in (None, "ablations"):
        from benchmarks.ablations import run as run_ab
        run_ab()

    if args.only == "dryrun":
        from repro.roofline.report import print_report
        print_report(pathlib.Path("results/dryrun/all.json"))

    print("\n== CSV summary (name,us_per_call,derived) ==")
    for name, us, derived in csv_rows:
        if isinstance(us, tuple):
            name, us, derived = us
        print(f"{name},{us:.2f},{derived}")

    if regressions:
        print(f"\nFAIL: {len(regressions)} kernel(s) regressed "
              f">{args.regression_threshold:.0%} or went missing "
              f"vs {args.baseline}")
        sys.exit(1)


if __name__ == "__main__":
    main()
