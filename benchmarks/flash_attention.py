"""Flash Attention benchmark — paper Table 3 + Fig. 14/15.

16 LLM-serving configurations; original (unoptimized) vs optimized kernel.
Correctness of both kernels is verified against the oracle at reduced shapes
(interpret mode); performance derives from the v5e analytic roofline model
(DESIGN.md §2.2). Also emits the roofline placement (Fig. 15 analogue).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.attention_model import (flash_attention_cost,
                                           naive_attention_cost, naive_oom)
from repro.kernels.flash_attention import attention_unoptimized, flash_attention
from repro.hw.specs import TPU_V5E

# paper Table 3: (name, B, A, S, D); + irregular-shape flags
CONFIGS = [
    ("llama3-8b/mistral-7b 2k", 1, 32, 2048, 128),
    ("llama3-8b/mistral-7b 4k", 1, 32, 4096, 128),
    ("llama3-8b batched B2", 2, 32, 2048, 128),
    ("llama3-8b batched B8", 8, 32, 2048, 128),
    ("llama3-70b 4k", 1, 64, 4096, 128),
    ("falcon-40b (A=71)", 1, 71, 2048, 64),
    ("gpt-neox-20b (D=96)", 1, 64, 2048, 96),
    ("qwen-7b/14b 8k", 1, 32, 8192, 128),
    ("qwen long-context 16k", 1, 32, 16384, 128),
    ("qwen-72b 8k", 1, 64, 8192, 128),
    ("deepseek-coder 16k", 1, 40, 16384, 128),
    ("deepseek large MoE 8k", 1, 48, 8192, 128),
    ("mixtral-8x7b B2 4k", 2, 32, 4096, 128),
    ("mixtral long-context 16k", 1, 32, 16384, 128),
    ("moe small-head B4 (D=64)", 4, 64, 4096, 64),
    ("frontier long-context 32k", 1, 32, 32768, 128),
]


def verify_kernels_correct() -> bool:
    """Both kernels vs oracle at reduced shapes (incl. the irregular A=71 and
    D=96 classes via non-pow2 dims)."""
    rng = np.random.default_rng(0)
    for (b, a, s, d) in [(1, 4, 256, 64), (1, 7, 128, 64), (2, 4, 128, 96)]:
        q = jnp.asarray(rng.standard_normal((b, a, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, a, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, a, s, d)), jnp.float32)
        want = ref.attention_ref(q, k, v, causal=True)
        got_naive = attention_unoptimized(q, k, v, causal=True, block_q=64)
        got_flash = flash_attention(q, k, v, causal=True, block_q=64,
                                    block_kv=64)
        np.testing.assert_allclose(np.asarray(got_naive), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(got_flash), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    return True


def run(csv_rows=None):
    verify_kernels_correct()
    print("\n== Flash Attention on TPU v5e (paper Table 3 / Fig. 14) ==")
    print(f"{'config':28s} {'naive TFLOPS':>12s} {'flash TFLOPS':>12s} "
          f"{'speedup':>8s} {'AI (F/B)':>9s} {'naive-OOM':>9s}")
    speedups = []
    rows = []
    for name, b, a, s, d in CONFIGS:
        nc = naive_attention_cost(b, a, s, d)
        fc = flash_attention_cost(b, a, s, d)
        sp = nc.t_total / fc.t_total
        speedups.append(sp)
        oom = naive_oom(b, a, s, d)
        print(f"{name:28s} {nc.tflops:12.1f} {fc.tflops:12.1f} {sp:7.1f}x "
              f"{fc.arithmetic_intensity:9.0f} {'yes' if oom else 'no':>9s}")
        rows.append({"config": name, "B": b, "A": a, "S": s, "D": d,
                     "naive_tflops": round(nc.tflops, 2),
                     "flash_tflops": round(fc.tflops, 2),
                     "speedup": round(sp, 2),
                     "eager_scores_oom": oom})
        if csv_rows is not None:
            csv_rows.append((f"fa:{name.replace(' ', '_').replace(',', '')}",
                             fc.t_total * 1e6, f"speedup={sp:.2f}"))
    gmean = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    no_regress = all(s >= 1.0 for s in speedups)
    long_ctx = [s for (nm, b, a, ss, d), s in zip(CONFIGS, speedups)
                if ss >= 16384]
    print(f"\nspeedup range: {min(speedups):.1f}x .. {max(speedups):.1f}x "
          f"(geomean {gmean:.1f}x); no regression: {no_regress}; "
          f"long-context (>=16k) mean: {np.mean(long_ctx):.1f}x")
    peak = TPU_V5E.peak_flops_bf16 / 1e12
    best = max(r["flash_tflops"] for r in rows)
    print(f"best optimized config reaches {best:.0f} TFLOPS = "
          f"{100 * best / peak:.0f}% of the {peak:.0f} TFLOPS bf16 roofline")
    return {"rows": rows, "geomean": gmean, "no_regression": no_regress,
            "min": min(speedups), "max": max(speedups)}


if __name__ == "__main__":
    run()
