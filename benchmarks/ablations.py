"""Ablations — paper §VII (domain knowledge vs model scale; stage structure).

1. no-KB-constraints: proposers without the hardware query's shape-aware
   configs (NVIDIA-default tiles) — the paper's 'LLM defaults to NVIDIA
   heuristics' argument.
2. stage subsets: restructuring stages disabled.
3. planner off (fixed default order) vs dependency-constrained planner.
4. best-of-k.
"""

from __future__ import annotations

import math

from repro.aibench import build_program, load_specs
from repro.forge import Forge, ForgeConfig

PROBLEMS = ["gemm_divide_sum", "gemm_max_subtract_gelu", "matmul_t_gelu",
            "gemm_bias_gelu", "matmul_min_subtract", "gemm_f64_sigmoid"]


def _run(names, **config_kw):
    forge = Forge(ForgeConfig(**config_kw))
    speedups = []
    for name in names:
        spec = next(s for s in load_specs() if s.name == name)
        report = forge.optimize_program(
            spec.name,
            build_program(spec.builder, spec.dims("ci"), "naive", meta=spec.meta),
            build_program(spec.builder, spec.dims("bench"), "naive", meta=spec.meta),
            tags=tuple(spec.tags), target_dtype=spec.target_dtype,
            rtol=spec.rtol, atol=spec.atol, meta=spec.meta)
        speedups.append(report.result.result.speedup)
    return math.exp(sum(math.log(max(s, 1e-9)) for s in speedups) / len(speedups))


def run():
    print("\n== Ablations (paper §VII) ==")
    full = _run(PROBLEMS)
    print(f"full pipeline                         geomean {full:7.2f}x")

    no_restructure = _run(PROBLEMS, stages_enabled=[
        "dtype_fix", "memory_access", "block_pointers", "persistent_kernel",
        "gpu_specific", "autotuning"])
    print(f"no algorithmic/discovery/fusion       geomean {no_restructure:7.2f}x")

    tuning_only = _run(PROBLEMS, stages_enabled=["gpu_specific", "autotuning"])
    print(f"gpu-specific+autotune only            geomean {tuning_only:7.2f}x")

    no_planner = _run(PROBLEMS, use_planner=False)
    print(f"planner off (fixed default order)     geomean {no_planner:7.2f}x")

    k2 = _run(PROBLEMS, best_of_k=2)
    print(f"best-of-k=2                           geomean {k2:7.2f}x")

    assert full >= no_restructure, "restructuring stages must matter"
    assert full >= tuning_only
    print("\nstage attribution confirmed: restructuring stages carry the "
          ">5x wins; tuning alone matches compilers (paper's thesis).")
    return {"full": full, "no_restructure": no_restructure,
            "tuning_only": tuning_only, "no_planner": no_planner, "k2": k2}


if __name__ == "__main__":
    run()
