"""End-to-end pipeline throughput benchmark: verification fast path on vs off.

    PYTHONPATH=src python -m benchmarks.pipeline_throughput
        [--min-speedup 1.5] [--out BENCH_pipeline.json] [--skip-warmup]

Times cold end-to-end optimization of the fixed backend-equivalence job set
(one job per structural family plus a family twin — the same set
``scripts/backend_equivalence.py`` gates on) twice through the serial
backend with an empty store: once with ``verify_fastpath="off"`` (the
uncached reference cascade) and once with ``"on"`` (memoized incremental
verify + cost-first screening). It then

* asserts **result equivalence** — per-job transform logs, optimized times,
  canonical schedules and proposal counts must be identical across modes
  (the fast path may only change *how fast* verification runs, never what
  it decides), and
* writes ``BENCH_pipeline.json`` recording both wall-clock times and the
  speedup, exiting non-zero when the speedup is below ``--min-speedup``
  (default 1.5x — the PR's acceptance bar) or any divergence was found.

A small untimed warmup job runs first so one-time JAX tracing/compilation
costs don't inflate whichever mode happens to run first.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

# the fixed gate set: two GEMM-family structures, one matmul-family, and a
# conv, so both pallas-templated and XLA-only verify paths are timed; the
# family twin exercises the two-phase leader/follower transfer path
GATE_SPECS = ("gemm_bias_gelu", "gemm_swish_tanh_scale", "matmul_t_gelu",
              "conv2d_gelu_scale")


def build_jobs():
    from repro.aibench import build_program, load_specs
    from repro.core import KernelJob

    specs = {s.name: s for s in load_specs()}
    jobs = []
    for name in GATE_SPECS:
        s = specs[name]
        jobs.append(KernelJob(
            s.name,
            build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
            build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
            tags=tuple(s.tags), target_dtype=s.target_dtype,
            rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    # family twin of the first job at halved dims: forces the two-phase
    # leader/follower transfer path
    s = specs[GATE_SPECS[0]]
    jobs.append(KernelJob(
        f"{s.name}_twin",
        build_program(s.builder,
                      {k: max(32, v // 2) for k, v in s.dims("ci").items()},
                      "naive", meta=s.meta),
        build_program(s.builder,
                      {k: max(64, v // 2) for k, v in s.dims("bench").items()},
                      "naive", meta=s.meta),
        tags=tuple(s.tags), target_dtype=s.target_dtype,
        rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    return jobs


def run_mode(mode: str):
    """Cold run of the whole job set (fresh Forge, no store on disk)."""
    from repro.forge import Forge, ForgeConfig
    from repro.ir.fingerprint import program_canonical

    t0 = time.perf_counter()
    with Forge(ForgeConfig(execution_backend="serial", workers=1,
                           verify_fastpath=mode)) as forge:
        report = forge.optimize_batch(build_jobs())
    dt = time.perf_counter() - t0
    rows = {}
    for r in report.results:
        rows[r.job.name] = {
            "fingerprint": r.fingerprint,
            "transform_log": r.result.transform_log.to_list(),
            "optimized_time": r.result.optimized_time,
            "original_time": r.result.original_time,
            "speedup": round(r.result.speedup, 9),
            "proposals": r.result.proposals,
            "canonical_schedule": program_canonical(
                r.result.bench_program)["schedule"],
            "transfer": r.transfer,
        }
    return rows, dt


def diff_modes(off_rows: dict, on_rows: dict):
    """Every field of every job must match across modes."""
    divergences = []
    for name in sorted(set(off_rows) | set(on_rows)):
        a, b = off_rows.get(name), on_rows.get(name)
        if a is None or b is None:
            divergences.append((name, "missing"))
            continue
        for field in a:
            if a[field] != b[field]:
                divergences.append((name, field))
    return divergences


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="fail below this off/on wall-clock ratio")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    ap.add_argument("--skip-warmup", action="store_true",
                    help="skip the untimed JAX warmup job")
    args = ap.parse_args()

    if not args.skip_warmup:
        # untimed: absorb one-time tracing/compilation costs shared by both
        # timed runs (JAX caches are process-global)
        from repro.forge import Forge, ForgeConfig
        with Forge(ForgeConfig(execution_backend="serial", workers=1,
                               verify_fastpath="off")) as forge:
            forge.optimize_batch(build_jobs()[:1])
        print("warmup done")

    print(f"== pipeline throughput ({len(GATE_SPECS) + 1} jobs, serial "
          f"backend, cold store) ==")
    off_rows, off_s = run_mode("off")
    print(f"  verify_fastpath=off  {off_s:7.1f}s")
    on_rows, on_s = run_mode("on")
    print(f"  verify_fastpath=on   {on_s:7.1f}s")
    speedup = off_s / on_s if on_s > 0 else float("inf")
    divergences = diff_modes(off_rows, on_rows)
    for name, field in divergences:
        print(f"  DIVERGED {name}.{field}:\n"
              f"    off: {off_rows.get(name, {}).get(field)!r}\n"
              f"    on:  {on_rows.get(name, {}).get(field)!r}")

    artifact = {
        "job_set": list(GATE_SPECS) + [f"{GATE_SPECS[0]}_twin"],
        "off_s": off_s,
        "on_s": on_s,
        "speedup": speedup,
        "min_speedup": args.min_speedup,
        "equivalent": not divergences,
        "jobs": {name: {"speedup": on_rows[name]["speedup"],
                        "proposals": on_rows[name]["proposals"],
                        "transfer": on_rows[name]["transfer"]}
                 for name in sorted(on_rows)},
    }
    pathlib.Path(args.out).write_text(json.dumps(artifact, indent=2))
    print(f"\nwrote {args.out}: fast path {speedup:.2f}x "
          f"({off_s:.1f}s -> {on_s:.1f}s), "
          f"{'results identical' if not divergences else 'DIVERGED'}")
    if divergences:
        print(f"FAIL: {len(divergences)} result divergence(s) between modes")
        return 1
    if speedup < args.min_speedup:
        print(f"FAIL: speedup {speedup:.2f}x below the "
              f"{args.min_speedup:.2f}x bar")
        return 1
    print(f"pipeline throughput OK (>= {args.min_speedup:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
