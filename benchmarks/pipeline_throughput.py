"""End-to-end pipeline throughput benchmark: verification fast path on vs off.

    PYTHONPATH=src python -m benchmarks.pipeline_throughput
        [--min-speedup 1.5] [--min-batch-improvement 1.4]
        [--out BENCH_pipeline.json] [--skip-warmup]

Two scenarios, both gated:

**Cold** — times cold end-to-end optimization of the fixed
backend-equivalence job set (one job per structural family plus a family
twin — the same set ``scripts/backend_equivalence.py`` gates on) twice
through the serial backend with an empty store: once with
``verify_fastpath="off"`` (the uncached reference cascade) and once with
``"on"`` (memoized incremental verify + cost-first screening). It asserts
**result equivalence** — per-job transform logs, optimized times, canonical
schedules and proposal counts must be identical across modes (the fast path
may only change *how fast* verification runs, never what it decides) — and
fails below ``--min-speedup`` (default 1.5x, the PR 5 acceptance bar).

**Batch** — a shared-family batch (one leader + N node-renamed twins, all
structurally identical) run under two configurations: PR 5 semantics
(per-job sessions only: ``shared_verify_cache_bytes=0``,
``batch_exec_planning=False``) and the cross-job configuration (shared
verify cache + batch execution planner, the defaults). Each configuration
times a fresh-Forge single-job run and a fresh-Forge full-batch run; the
figure of merit is the **marginal cost of a twin**,
``(T_batch - T_single) / N`` — under PR 5 every twin re-executes the oracle
prep and every candidate group; with cross-job sharing twins hit the shared
cache. Fails below ``--min-batch-improvement`` (default 1.4x marginal
improvement), on any cross-configuration result divergence, or if a
``verify_fastpath="check"`` pass over the same batch (every shared hit
byte-compared against a fresh execution) raises.

**Search** — proposals-per-win (stage-loop proposals ÷ improved jobs) for
cold, warm-prior, and transfer scenario runs under two search policies: the
PR 6 baseline (``prior_policy="counts"``, no cost ranking) and the learned
policy (mined priors + cost-ranked proposals, the defaults). Gated: the
learned policy must be strictly below the baseline on the warm-prior
scenario, at least ``--min-transfer-reduction`` (default 20%) below it on
the transfer scenario, never regress any per-job speedup, and stay under
``--max-proposals-per-win`` when that absolute cap is set.

``BENCH_pipeline.json`` records all scenarios (the batch one under a
``"batch"`` key, including the shared run's verify/planner counters, and
the search one under a ``"search"`` key).

A small untimed warmup job runs first so one-time JAX tracing/compilation
costs don't inflate whichever mode happens to run first.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

# the fixed gate set: two GEMM-family structures, one matmul-family, and a
# conv, so both pallas-templated and XLA-only verify paths are timed; the
# family twin exercises the two-phase leader/follower transfer path
GATE_SPECS = ("gemm_bias_gelu", "gemm_swish_tanh_scale", "matmul_t_gelu",
              "conv2d_gelu_scale")


def build_jobs():
    from repro.aibench import build_program, load_specs
    from repro.core import KernelJob

    specs = {s.name: s for s in load_specs()}
    jobs = []
    for name in GATE_SPECS:
        s = specs[name]
        jobs.append(KernelJob(
            s.name,
            build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
            build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
            tags=tuple(s.tags), target_dtype=s.target_dtype,
            rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    # family twin of the first job at halved dims: forces the two-phase
    # leader/follower transfer path
    s = specs[GATE_SPECS[0]]
    jobs.append(KernelJob(
        f"{s.name}_twin",
        build_program(s.builder,
                      {k: max(32, v // 2) for k, v in s.dims("ci").items()},
                      "naive", meta=s.meta),
        build_program(s.builder,
                      {k: max(64, v // 2) for k, v in s.dims("bench").items()},
                      "naive", meta=s.meta),
        tags=tuple(s.tags), target_dtype=s.target_dtype,
        rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    return jobs


def build_batch_jobs(twins: int = 3):
    """One leader plus ``twins`` node-renamed copies — structurally and
    numerically identical jobs whose node names all differ. Name-invariant
    fingerprints collide (exact replay kicks in for the twins) while any
    name-*bound* key would miss; the marginal cost of a twin is therefore
    pure verification work — exactly what cross-job sharing removes."""
    from repro.aibench import build_program, load_specs
    from repro.core import KernelJob
    from repro.ir.schedule import rename_program

    s = {sp.name: sp for sp in load_specs()}[GATE_SPECS[0]]
    ci = build_program(s.builder, s.dims("ci"), "naive", meta=s.meta)
    bench = build_program(s.builder, s.dims("bench"), "naive", meta=s.meta)
    jobs = [KernelJob(s.name, ci, bench, tags=tuple(s.tags),
                      target_dtype=s.target_dtype, rtol=s.rtol, atol=s.atol,
                      meta=dict(s.meta))]
    for i in range(twins):
        jobs.append(KernelJob(
            f"{s.name}_shared{i}",
            rename_program(ci, f"t{i}_"), rename_program(bench, f"t{i}_"),
            tags=tuple(s.tags), target_dtype=s.target_dtype,
            rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    return jobs


def _rows_for(report):
    from repro.ir.fingerprint import program_canonical

    rows = {}
    for r in report.results:
        rows[r.job.name] = {
            "fingerprint": r.fingerprint,
            "transform_log": r.result.transform_log.to_list(),
            "optimized_time": r.result.optimized_time,
            "original_time": r.result.original_time,
            "speedup": round(r.result.speedup, 9),
            "proposals": r.result.proposals,
            "canonical_schedule": program_canonical(
                r.result.bench_program)["schedule"],
        }
    return rows


def run_batch_config(jobs, **overrides):
    """Fresh-Forge single-leader run, then fresh-Forge full-batch run (both
    cold stores) under one configuration. Returns (rows, single_s, batch_s,
    verify_stats_dict)."""
    from repro.forge import Forge, ForgeConfig

    t0 = time.perf_counter()
    with Forge(ForgeConfig(execution_backend="serial", workers=1,
                           verify_fastpath="on", **overrides)) as forge:
        forge.optimize_batch(jobs[:1])
    single_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    with Forge(ForgeConfig(execution_backend="serial", workers=1,
                           verify_fastpath="on", **overrides)) as forge:
        report = forge.optimize_batch(jobs)
    batch_s = time.perf_counter() - t0
    verify = report.verify.as_dict() if report.verify is not None else {}
    return _rows_for(report), single_s, batch_s, verify


def run_batch_scenario(min_improvement: float, twins: int = 3):
    """The shared-family batch scenario; returns (artifact_section, failed)."""
    jobs = build_batch_jobs(twins)
    print(f"\n== shared-family batch (1 leader + {twins} renamed twins, "
          f"serial backend, cold store) ==")
    pr5_rows, pr5_single, pr5_batch, _ = run_batch_config(
        jobs, shared_verify_cache_bytes=0, batch_exec_planning=False)
    pr5_marginal = max(pr5_batch - pr5_single, 0.0) / twins
    print(f"  per-job sessions (PR 5)   single {pr5_single:6.1f}s  "
          f"batch {pr5_batch:6.1f}s  marginal {pr5_marginal:6.2f}s/twin")
    sh_rows, sh_single, sh_batch, sh_verify = run_batch_config(jobs)
    sh_marginal = max(sh_batch - sh_single, 0.0) / twins
    print(f"  shared cache + planner    single {sh_single:6.1f}s  "
          f"batch {sh_batch:6.1f}s  marginal {sh_marginal:6.2f}s/twin")
    improvement = (pr5_marginal / sh_marginal if sh_marginal > 0
                   else float("inf"))
    print(f"  marginal improvement {improvement:.2f}x  "
          f"(shared: {sh_verify.get('shared_group_hits', 0)} shared group "
          f"hits, {sh_verify.get('shared_oracle_hits', 0)} shared oracle "
          f"hits; planner: {sh_verify.get('planner_signatures', 0)} "
          f"signatures, {sh_verify.get('planner_deduped_jobs', 0)} jobs "
          f"warm-started)")

    # bit-identical results: per job across configurations, and every twin
    # against the leader within each configuration (twins are exact-
    # fingerprint replays of the leader, sharing may not perturb them)
    divergences = diff_modes(pr5_rows, sh_rows)
    leader = jobs[0].name
    for rows, tag in ((pr5_rows, "pr5"), (sh_rows, "shared")):
        for name, row in rows.items():
            if name == leader:
                continue
            for field in ("transform_log", "speedup", "optimized_time",
                          "canonical_schedule"):
                if row[field] != rows[leader][field]:
                    divergences.append((f"{tag}:{name}", field))
    for name, field in divergences:
        print(f"  DIVERGED {name}.{field}")

    # check mode: every shared-cache hit byte-compared against a fresh
    # execution; a single divergent byte raises VerifyFastpathDivergence
    check_ok, check_err = True, None
    try:
        from repro.forge import Forge, ForgeConfig
        with Forge(ForgeConfig(execution_backend="serial", workers=1,
                               verify_fastpath="check")) as forge:
            forge.optimize_batch(jobs)
        print("  check mode: all shared hits byte-identical")
    except Exception as e:  # VerifyFastpathDivergence or anything else
        check_ok, check_err = False, f"{type(e).__name__}: {e}"
        print(f"  check mode FAILED: {check_err}")

    section = {
        "leader": leader,
        "twins": twins,
        "pr5": {"single_s": pr5_single, "batch_s": pr5_batch,
                "marginal_s": pr5_marginal},
        "shared": {"single_s": sh_single, "batch_s": sh_batch,
                   "marginal_s": sh_marginal, "verify_stats": sh_verify},
        "marginal_improvement": improvement,
        "min_improvement": min_improvement,
        "equivalent": not divergences,
        "check_ok": check_ok,
        "check_error": check_err,
    }
    failed = (bool(divergences) or not check_ok
              or improvement < min_improvement)
    return section, failed


def _search_rows(results):
    rows = {}
    for r in results:
        rows[r.job.name] = {
            "proposals": r.result.proposals,
            "improved": r.result.optimized_time < r.result.original_time,
            "speedup": round(r.result.speedup, 9),
            "transfer": r.transfer,
        }
    return rows


def _proposals_per_win(rows: dict) -> float:
    proposals = sum(v["proposals"] for v in rows.values())
    wins = sum(1 for v in rows.values() if v["improved"])
    return proposals / wins if wins else float("inf")


def run_search_policy(policy: str, cost_rank: bool):
    """Cold, warm-prior, and transfer scenario runs under one search policy
    (serial backend throughout, so proposal counts are deterministic).

    * cold     — empty store, empty history: ordering falls back to the
                 cost model alone (or KB order under the legacy policy).
    * warm     — fresh store, history mined from the cold run: pure
                 prior-ordering effect, no replay/transfer.
    * transfer — the cold run's store serves the family twin through the
                 graded ladder (different dims, same builders).
    """
    from repro.core import ForgeConfig, ForgePipeline, OptimizationEngine
    from repro.core.history import History

    def make_engine(hist):
        cfg = ForgeConfig(execution_backend="serial", workers=1,
                          prior_policy=policy,
                          cost_rank_proposals=cost_rank)
        return OptimizationEngine(ForgePipeline(config=cfg, history=hist),
                                  config=cfg)

    jobs = build_jobs()
    base, twin = jobs[:-1], jobs[-1]
    hist = History()

    cold_eng = make_engine(hist)
    cold = _search_rows(cold_eng.run_batch(base))

    warm = _search_rows(make_engine(hist).run_batch(build_jobs()[:-1]))

    transfer_res = cold_eng.submit(twin)
    transfer = _search_rows([transfer_res])

    return {
        "cold": cold, "warm": warm, "transfer": transfer,
        "ppw": {"cold": _proposals_per_win(cold),
                "warm": _proposals_per_win(warm),
                "transfer": _proposals_per_win(transfer)},
        "transfer_hit": bool(transfer_res.transfer),
    }


def run_search_scenario(max_ppw: float, min_transfer_reduction: float = 0.2):
    """Learned-search gate: proposals-per-win under the learned policy
    (mined priors + cost-ranked proposals, the defaults) must beat the
    PR 6 baseline policy (flat counts, KB candidate order) strictly on the
    warm-prior scenario and by ``min_transfer_reduction`` on the transfer
    scenario — with every per-job speedup unchanged or better. Returns
    (artifact_section, failed)."""
    print("\n== learned search (proposals-per-win: cold / warm-prior / "
          "transfer, serial backend) ==")
    legacy = run_search_policy("counts", cost_rank=False)
    learned = run_search_policy("mined", cost_rank=True)
    for tag, res in (("counts+kb-order (PR 6)", legacy),
                     ("mined+cost-rank", learned)):
        p = res["ppw"]
        print(f"  {tag:24s} cold {p['cold']:5.2f}  warm {p['warm']:5.2f}  "
              f"transfer {p['transfer']:5.2f}")

    problems = []
    if not learned["transfer_hit"]:
        problems.append("learned transfer scenario did not take the "
                        "family-ladder path")
    if not (learned["ppw"]["warm"] < legacy["ppw"]["warm"]):
        problems.append(
            f"warm proposals-per-win {learned['ppw']['warm']:.2f} not "
            f"strictly below the baseline {legacy['ppw']['warm']:.2f}")
    transfer_bar = legacy["ppw"]["transfer"] * (1.0 - min_transfer_reduction)
    if not (learned["ppw"]["transfer"] <= transfer_bar):
        problems.append(
            f"transfer proposals-per-win {learned['ppw']['transfer']:.2f} "
            f"above the {min_transfer_reduction:.0%}-reduction bar "
            f"{transfer_bar:.2f} (baseline "
            f"{legacy['ppw']['transfer']:.2f})")
    if max_ppw > 0:
        worst = max(learned["ppw"]["warm"], learned["ppw"]["transfer"])
        if worst > max_ppw:
            problems.append(f"learned warm/transfer proposals-per-win "
                            f"{worst:.2f} above --max-proposals-per-win "
                            f"{max_ppw:.2f}")
    # search ordering may only change *how fast* a win is found, never make
    # any job slower than the baseline policy found it
    for scen in ("cold", "warm", "transfer"):
        for name, row in learned[scen].items():
            base_speedup = legacy[scen][name]["speedup"]
            if row["speedup"] < base_speedup * (1 - 1e-9):
                problems.append(
                    f"{scen}:{name} speedup regressed "
                    f"{base_speedup} -> {row['speedup']}")
    for p in problems:
        print(f"  FAIL(search): {p}")
    if not problems:
        print(f"  search ordering OK (warm "
              f"{legacy['ppw']['warm']:.2f} -> {learned['ppw']['warm']:.2f}, "
              f"transfer {legacy['ppw']['transfer']:.2f} -> "
              f"{learned['ppw']['transfer']:.2f})")

    section = {
        "baseline": {"policy": "counts", "cost_rank_proposals": False,
                     "ppw": legacy["ppw"], "jobs": {
                         "cold": legacy["cold"], "warm": legacy["warm"],
                         "transfer": legacy["transfer"]}},
        "learned": {"policy": "mined", "cost_rank_proposals": True,
                    "ppw": learned["ppw"], "jobs": {
                        "cold": learned["cold"], "warm": learned["warm"],
                        "transfer": learned["transfer"]}},
        "min_transfer_reduction": min_transfer_reduction,
        "max_proposals_per_win": max_ppw,
        "problems": problems,
    }
    return section, bool(problems)


def run_mode(mode: str):
    """Cold run of the whole job set (fresh Forge, no store on disk)."""
    from repro.forge import Forge, ForgeConfig
    from repro.ir.fingerprint import program_canonical

    t0 = time.perf_counter()
    with Forge(ForgeConfig(execution_backend="serial", workers=1,
                           verify_fastpath=mode)) as forge:
        report = forge.optimize_batch(build_jobs())
    dt = time.perf_counter() - t0
    rows = {}
    for r in report.results:
        rows[r.job.name] = {
            "fingerprint": r.fingerprint,
            "transform_log": r.result.transform_log.to_list(),
            "optimized_time": r.result.optimized_time,
            "original_time": r.result.original_time,
            "speedup": round(r.result.speedup, 9),
            "proposals": r.result.proposals,
            "canonical_schedule": program_canonical(
                r.result.bench_program)["schedule"],
            "transfer": r.transfer,
        }
    return rows, dt


def diff_modes(off_rows: dict, on_rows: dict):
    """Every field of every job must match across modes."""
    divergences = []
    for name in sorted(set(off_rows) | set(on_rows)):
        a, b = off_rows.get(name), on_rows.get(name)
        if a is None or b is None:
            divergences.append((name, "missing"))
            continue
        for field in a:
            if a[field] != b[field]:
                divergences.append((name, field))
    return divergences


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="fail below this off/on wall-clock ratio")
    ap.add_argument("--min-batch-improvement", type=float, default=1.4,
                    help="fail below this PR5/shared marginal-cost ratio "
                         "in the shared-family batch scenario")
    ap.add_argument("--twins", type=int, default=3,
                    help="renamed twins in the batch scenario")
    ap.add_argument("--max-proposals-per-win", type=float, default=0.0,
                    help="fail if the learned policy's warm/transfer "
                         "proposals-per-win exceeds this (0 = no absolute "
                         "cap; the relative gates always apply)")
    ap.add_argument("--min-transfer-reduction", type=float, default=0.2,
                    help="required proposals-per-win reduction vs the "
                         "baseline policy on the transfer scenario")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    ap.add_argument("--skip-warmup", action="store_true",
                    help="skip the untimed JAX warmup job")
    args = ap.parse_args()

    if not args.skip_warmup:
        # untimed: absorb one-time tracing/compilation costs shared by both
        # timed runs (JAX caches are process-global)
        from repro.forge import Forge, ForgeConfig
        with Forge(ForgeConfig(execution_backend="serial", workers=1,
                               verify_fastpath="off")) as forge:
            forge.optimize_batch(build_jobs()[:1])
        print("warmup done")

    print(f"== pipeline throughput ({len(GATE_SPECS) + 1} jobs, serial "
          f"backend, cold store) ==")
    off_rows, off_s = run_mode("off")
    print(f"  verify_fastpath=off  {off_s:7.1f}s")
    on_rows, on_s = run_mode("on")
    print(f"  verify_fastpath=on   {on_s:7.1f}s")
    speedup = off_s / on_s if on_s > 0 else float("inf")
    divergences = diff_modes(off_rows, on_rows)
    for name, field in divergences:
        print(f"  DIVERGED {name}.{field}:\n"
              f"    off: {off_rows.get(name, {}).get(field)!r}\n"
              f"    on:  {on_rows.get(name, {}).get(field)!r}")

    batch_section, batch_failed = run_batch_scenario(
        args.min_batch_improvement, twins=args.twins)

    search_section, search_failed = run_search_scenario(
        args.max_proposals_per_win, args.min_transfer_reduction)

    artifact = {
        "job_set": list(GATE_SPECS) + [f"{GATE_SPECS[0]}_twin"],
        "off_s": off_s,
        "on_s": on_s,
        "speedup": speedup,
        "min_speedup": args.min_speedup,
        "equivalent": not divergences,
        "jobs": {name: {"speedup": on_rows[name]["speedup"],
                        "proposals": on_rows[name]["proposals"],
                        "transfer": on_rows[name]["transfer"]}
                 for name in sorted(on_rows)},
        "batch": batch_section,
        "search": search_section,
    }
    pathlib.Path(args.out).write_text(json.dumps(artifact, indent=2))
    print(f"\nwrote {args.out}: fast path {speedup:.2f}x "
          f"({off_s:.1f}s -> {on_s:.1f}s), "
          f"{'results identical' if not divergences else 'DIVERGED'}; "
          f"batch marginal {batch_section['marginal_improvement']:.2f}x")
    failed = False
    if divergences:
        print(f"FAIL: {len(divergences)} result divergence(s) between modes")
        failed = True
    if speedup < args.min_speedup:
        print(f"FAIL: speedup {speedup:.2f}x below the "
              f"{args.min_speedup:.2f}x bar")
        failed = True
    if batch_failed:
        print(f"FAIL: batch scenario "
              f"(improvement {batch_section['marginal_improvement']:.2f}x "
              f"vs {args.min_batch_improvement:.2f}x bar, "
              f"equivalent={batch_section['equivalent']}, "
              f"check_ok={batch_section['check_ok']})")
        failed = True
    if search_failed:
        print(f"FAIL: search scenario "
              f"({len(search_section['problems'])} problem(s); see "
              f"FAIL(search) lines above)")
        failed = True
    if failed:
        return 1
    print(f"pipeline throughput OK (cold >= {args.min_speedup:.2f}x, "
          f"batch marginal >= {args.min_batch_improvement:.2f}x, "
          f"search proposals-per-win gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
