"""Level-2 suite benchmark — paper Fig. 2-8 + §VI-C.

Runs the full pipeline over all 28 problems through the fleet
``OptimizationEngine`` (bounded worker pool + fingerprint-keyed result
cache), reporting per-family TFLOPS (original accounting) for the four
backends and the headline aggregates (geomean, %improved, >5x set,
correctness, cache hits)."""

from __future__ import annotations

import collections
import math

from repro.aibench import SuiteRunner
from repro.forge import ForgeConfig


def run(csv_path=None, families=None, workers=1, cache_path=None,
        backend="thread", runs=1, config=None):
    """``runs > 1`` re-submits the suite through the same engine so the
    second pass exercises the result cache (replay path). ``config`` is a
    full :class:`ForgeConfig`; the ``workers``/``cache_path``/``backend``
    kwargs are shorthands for the common case (``backend`` selects the
    engine's execution backend: serial / thread / process)."""
    print("\n== KernelBench-L2 suite (paper Fig. 2-8) ==")
    if config is None:
        config = ForgeConfig(
            workers=workers,
            execution_backend=backend,
            cache_path=str(cache_path) if cache_path else None)
    runner = SuiteRunner(config, csv_path=csv_path, families=families)
    try:
        summary = runner.run()
        for _ in range(max(0, runs - 1)):
            summary = runner.run()
    finally:
        # the process backend keeps spawned workers warm between batches;
        # release them once the suite is done
        runner.close()

    by_family = collections.defaultdict(list)
    for r in summary.results:
        by_family[r.family].append(r)
    print("\nper-family geomean speedup vs best baseline "
          "(paper: GEMM 1.28x, MatMul 1.76x, conv ~1.0x):")
    for fam, rs in sorted(by_family.items()):
        g = math.exp(sum(math.log(max(r.speedup_vs_best_baseline, 1e-9))
                         for r in rs) / len(rs))
        ge = math.exp(sum(math.log(max(r.speedup_vs_eager, 1e-9))
                          for r in rs) / len(rs))
        print(f"  {fam:9s} n={len(rs):2d}  vs-best {g:6.2f}x   vs-eager {ge:6.2f}x")

    stats = summary.engine_stats
    print(f"\ngeomean vs eager:  {summary.geomean_vs_eager:.2f}x "
          f"(paper: 1.17x over eager)")
    print(f"geomean vs best:   {summary.geomean_vs_best:.2f}x")
    print(f"improved:          {summary.pct_improved:.0f}% (paper: 67%)")
    print(f">5x vs best:       {len(summary.over_5x)} kernels "
          f"(paper: 9, up to 82x): "
          f"{[(r.name, round(r.speedup_vs_best_baseline, 1)) for r in summary.over_5x]}")
    print(f"100% correct:      {summary.all_correct} (paper: 100%)")
    if stats:
        print(f"engine:            {stats.jobs} jobs, "
              f"{stats.cache_hits} exact hits, "
              f"{stats.cache_misses} misses, "
              f"{stats.family_transfers} family transfers, "
              f"{stats.transfer_fallbacks} transfer fallbacks, "
              f"{stats.replay_fallbacks} replay fallbacks")
    vstats = summary.verify_stats
    if vstats:
        print(f"verify:            {vstats.group_hits} group hits / "
              f"{vstats.group_misses} misses, "
              f"{vstats.oracle_hits} oracle hits / "
              f"{vstats.oracle_misses} misses, "
              f"{vstats.shared_group_hits} shared group hits, "
              f"{vstats.shared_oracle_hits} shared oracle hits, "
              f"{vstats.screened} screened")
        print(f"planner:           {vstats.planner_signatures} duplicated "
              f"signatures pre-executed, "
              f"{vstats.planner_deduped_jobs} jobs warm-started, "
              f"{vstats.planner_group_execs} group execs / "
              f"{vstats.planner_oracle_preps} oracle preps hoisted")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--backend", default="thread",
                    choices=["serial", "thread", "process"])
    ap.add_argument("--cache", default=None,
                    help="result-store path (warm store)")
    ap.add_argument("--runs", type=int, default=1)
    args = ap.parse_args()
    run(workers=args.workers, backend=args.backend, cache_path=args.cache,
        runs=args.runs)
