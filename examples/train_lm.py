"""End-to-end training driver: train a qwen3-family model (~28M params at
the example scale; pass a bigger config for ~100M+) for a few hundred steps
on synthetic data, with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(CPU-scale by default; the same Trainer drives pod-scale runs through
``repro.launch.train`` with the production mesh.)
"""

import argparse
import dataclasses
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import get_config
from repro.models.model import RuntimeFlags
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    # qwen3 family at example scale (CPU-trainable in minutes)
    cfg = dataclasses.replace(
        get_config("qwen3-8b"), num_layers=6, d_model=512, num_heads=8,
        kv_heads=4, d_ff=2048, vocab=4096, head_dim=64)
    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")

    trainer = Trainer(
        cfg, seq_len=args.seq_len, global_batch=args.global_batch,
        flags=RuntimeFlags(remat=False, chunked_attention=False),
        tcfg=TrainConfig(optimizer=AdamWConfig(
            lr=3e-3, total_steps=args.steps,
            warmup_steps=max(args.steps // 20, 5))),
        ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 4, 10))
    trainer.maybe_resume()

    n_params = sum(x.size for x in __import__("jax").tree.leaves(trainer.params))
    print(f"training qwen3-family model: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, ckpt -> {ckpt_dir}")
    hist = trainer.train(args.steps)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"({sum(h['sec'] for h in hist):.0f}s)")
    assert last < first * 0.9, "expected a clear loss reduction"
    print("OK")


if __name__ == "__main__":
    main()
