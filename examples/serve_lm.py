"""Batched serving example: greedy generation over request waves.

    PYTHONPATH=src python examples/serve_lm.py
"""

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("qwen3-8b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(cfg, params, max_len=48, slots=3)
    rng = np.random.default_rng(0)
    n = 6
    for i in range(n):
        engine.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16),
                              max_new_tokens=12))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {total} new tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.generated}")
    assert len(done) == n
    print("OK")


if __name__ == "__main__":
    main()
