"""Flash-Attention before/after: the paper's §VI-E experiment as a script.

    PYTHONPATH=src python examples/optimize_flash_attention.py

Validates the unoptimized and optimized kernels against the oracle (interpret
mode), shows the shape-aware tile selection from the hardware query system,
and reports the modeled v5e speedup per serving configuration.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.hw.query import HardwareQuery
from repro.hw.specs import TPU_V5E
from repro.kernels import ref
from repro.kernels.attention_model import (flash_attention_cost,
                                           naive_attention_cost)
from repro.kernels.flash_attention import attention_unoptimized, flash_attention


def main():
    rng = np.random.default_rng(0)
    b, h, s, d = 2, 8, 256, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)

    want = ref.attention_ref(q, k, v, causal=True)
    naive = attention_unoptimized(q, k, v, causal=True)
    flash = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("correctness: naive == flash == oracle (interpret mode) OK")

    hw = HardwareQuery(TPU_V5E)
    for (ss, dd) in [(2048, 128), (8192, 128), (32768, 128), (2048, 64)]:
        p = hw.get_attention_params(ss, ss, dd)
        nc = naive_attention_cost(1, 32, ss, dd)
        fc = flash_attention_cost(1, 32, ss, dd)
        print(f"S={ss:6d} D={dd:4d}: query tiles (bq={p.block_m}, "
              f"bkv={p.block_n})  {nc.tflops:6.1f} -> {fc.tflops:6.1f} TFLOPS "
              f"({nc.t_total/fc.t_total:5.1f}x)")


if __name__ == "__main__":
    main()
