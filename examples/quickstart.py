"""Quickstart: optimize one kernel with the Forge pipeline.

    PYTHONPATH=src python examples/quickstart.py

Takes a functionally correct but unoptimized kernel program (naive Pallas
matmul + separate epilogue launches — the KernelFalcon-analogue starting
point), runs the nine-stage CoVeR pipeline against the TPU v5e knowledge
base, and prints the per-stage trajectory and the verified speedup.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.aibench import build_program, load_specs
from repro.forge import Forge, ForgeConfig, ForgeObserver
from repro.ir.cost import CostModel


class StageLogger(ForgeObserver):
    """Observers replace driver-side print plumbing: this one streams the
    per-stage trajectory as the pipeline runs."""

    def on_stage_complete(self, job_name, r):
        status = (f"{r.speedup:5.2f}x via {r.description}" if r.improved
                  else "no verified improvement (original kept)")
        print(f"  {r.stage:18s} [{r.iterations} CoVeR iter] {status}")


def main():
    spec = next(s for s in load_specs() if s.name == "gemm_max_subtract_gelu")
    ci = build_program(spec.builder, spec.dims("ci"), "naive")
    bench = build_program(spec.builder, spec.dims("bench"), "naive")

    print("== input kernel (unoptimized) ==")
    print(bench.describe())

    forge = Forge(ForgeConfig(), observers=[StageLogger()])
    print("\n== stage log ==")
    res = forge.optimize_program(spec.name, ci, bench, tags=tuple(spec.tags),
                                 rtol=spec.rtol, atol=spec.atol).result.result

    print("\n== optimized kernel ==")
    print(res.bench_program.describe())

    cost = CostModel().program_cost(res.bench_program)
    print(f"\nmodeled v5e time: {res.original_time*1e6:8.1f}us -> "
          f"{res.optimized_time*1e6:8.1f}us  ({res.speedup:.1f}x, "
          f"{cost.tflops_effective:.1f} effective TFLOPS under original "
          f"accounting)")
    assert res.speedup > 1.0
    print("\nOK — correctness verified against the jnp oracle at every step.")


if __name__ == "__main__":
    main()
