import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh without allocating a single weight.

MUST set XLA_FLAGS before ANY other import (jax locks the device count on
first init) — hence the module-top assignment above.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single --out results/qwen3_train_single.json

One cell per process by default (compilation caches/arenas are per-process;
the orchestrator ``dryrun_all.py`` fans out subprocesses and merges JSON).
"""

import argparse
import json
import pathlib
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.configs.shapes import SHAPES, applicability
from repro.launch.input_specs import (abstract_cache, abstract_opt_state,
                                      abstract_params, input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.model import RuntimeFlags, decode_step, prefill
from repro.roofline.analyze import collective_bytes
from repro.train.train_step import TrainConfig, make_train_step


def count_params(abstract_p) -> float:
    return float(sum(x.size for x in jax.tree.leaves(abstract_p)))


def active_params(cfg: ModelConfig, abstract_p) -> float:
    """N_active: MoE experts count at top_k/E weight."""
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(abstract_p)[0]:
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        is_expert = cfg.moe is not None and any(
            str(k) in ("wi", "wg", "wo") for k in keys) and leaf.ndim >= 3 \
            and cfg.moe.num_experts in leaf.shape
        if is_expert:
            total += leaf.size * cfg.moe.top_k / cfg.moe.num_experts
        else:
            total += leaf.size
    return float(total)


def model_flops(cfg: ModelConfig, shape, n_params: float,
                n_active: float) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n = n_active if cfg.moe else n_params
    if shape.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             donate: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicability(cfg, shape_name)
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rec["n_devices"] = mesh.devices.size
    variant = os.environ.get("REPRO_VARIANT", "")
    vtok = {t.split("=")[0]: (t.split("=")[1] if "=" in t else True)
            for t in variant.split(",") if t}
    rec["variant"] = variant
    flags = RuntimeFlags(
        use_pallas=False, chunked_attention=True,
        remat=(shape.kind == "train") and not vtok.get("no_remat"),
        loss_chunks=int(vtok.get("loss_chunks", 8)))

    t0 = time.time()
    params = abstract_params(cfg, mesh, jnp.bfloat16)
    n_params = count_params(params)
    n_active = active_params(cfg, params)
    rec["n_params"] = n_params
    rec["n_active_params"] = n_active
    rec["model_flops"] = model_flops(cfg, shape, n_params, n_active)

    # activation-heavy train cells use gradient accumulation (standard
    # practice; microbatch counts recorded in the cell output)
    microbatches = {"llava-next-34b": 4, "granite-moe-3b-a800m": 1,
                    "qwen3-8b": 2, "grok-1-314b": 2,
                    "recurrentgemma-2b": 8, "mamba2-780m": 2}.get(arch, 1) \
        if shape.kind == "train" else 1
    if "mb" in vtok:
        microbatches = int(vtok["mb"])
    rec["microbatches"] = microbatches

    with mesh:
        if shape.kind == "train":
            opt = abstract_opt_state(cfg, params, mesh)
            batch = input_specs(cfg, shape, mesh)
            step_fn = make_train_step(cfg, flags,
                                      TrainConfig(microbatches=microbatches))
            jitted = jax.jit(step_fn,
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            batch = input_specs(cfg, shape, mesh)
            # the produced cache must come out sharded like the decode cells
            # consume it; without out_shardings SPMD may replicate it
            cache_sh = jax.tree.map(lambda a: a.sharding,
                                    abstract_cache(cfg, shape, mesh))
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.sharding.rules import dp_prefix_for
            logits_sh = NamedSharding(
                mesh, P(dp_prefix_for(mesh, shape.global_batch),
                        "model" if cfg.vocab % mesh.shape["model"] == 0
                        else None))
            jitted = jax.jit(lambda p, b: prefill(cfg, p, b, flags),
                             out_shardings=(logits_sh, cache_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            kv_dtype = jnp.int8 if vtok.get("kvq") == "int8" else jnp.bfloat16
            cache = abstract_cache(cfg, shape, mesh, dtype=kv_dtype)
            batch = input_specs(cfg, shape, mesh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.sharding.rules import dp_prefix_for
            cache_sh = jax.tree.map(lambda a: a.sharding, cache)
            logits_sh = NamedSharding(
                mesh, P(dp_prefix_for(mesh, shape.global_batch),
                        "model" if cfg.vocab % mesh.shape["model"] == 0
                        else None))
            jitted = jax.jit(
                lambda p, c, t, i: decode_step(cfg, p, c, t, i, flags),
                donate_argnums=(1,) if donate else (),
                out_shardings=(logits_sh, cache_sh))
            lowered = jitted.lower(params, cache, batch["tokens"], pos)
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    text = compiled.as_text()
    # CPU-backend artifact (documented in EXPERIMENTS.md §Dry-run): the CPU
    # backend legalizes bf16 compute to f32 and hoists the loop-invariant
    # FSDP weight all-gathers out of the layer scan, materializing f32
    # stacked-weight buffers (and their backward mirrors) that do not exist
    # on the TPU target (bf16, gathered per layer inside the loop). Quantify
    # them so the projected-TPU peak is reportable alongside the raw one.
    import re as _re
    artifact = 0
    for m in _re.finditer(r"=\s*f32\[(\d+(?:,\d+)*)\]\S*\s+all-gather", text):
        dims = [int(d) for d in m.group(1).split(",")]
        if dims and dims[0] == cfg.num_layers and len(dims) >= 3:
            n = 1
            for d in dims:
                n *= d
            artifact += 4 * n
    if shape.kind == "train":
        artifact *= 2  # backward holds the mirrored f32 stacked grads
    peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_per_device": peak,
        "cpu_backend_artifact_bytes": int(artifact),
        "peak_projected_tpu": int(peak - artifact),
    }
    rec["fits_hbm"] = rec["memory"]["peak_projected_tpu"] <= 16 * 2**30
    rec["fits_hbm_raw_cpu"] = peak <= 16 * 2**30
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    rec["cost_raw"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0)),
                       "transcendentals": float(ca.get("transcendentals", 0.0))}
    # the backend's cost_analysis counts while-loop bodies ONCE; the walker
    # multiplies by known_trip_count (scan-over-layers, microbatches, chunked
    # attention). flops/collectives exact per-chip; bytes scaled by the same
    # loop multiplier (documented approximation).
    from repro.roofline.hlo_walker import walk
    w = walk(text)
    ratio = (w.flops / rec["cost_raw"]["flops"]
             if rec["cost_raw"]["flops"] > 0 else 1.0)
    rec["cost"] = {
        "flops": float(w.flops),                       # per-chip, trip-exact
        "bytes": float(w.hbm_bytes),                   # per-chip, trip-exact
        "loop_multiplier": float(ratio),
    }
    rec["collectives"] = dict(w.coll_by_kind, total=float(w.coll_bytes))
    rec["collectives_unrolled_raw"] = collective_bytes(text)
    rec["hlo_lines"] = text.count("\n")
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multipod"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    try:
        rec = run_cell(args.arch, args.shape, args.mesh)
    except Exception as e:  # noqa: BLE001 — a failing cell is a reportable bug
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    js = json.dumps(rec, indent=2)
    if args.out:
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.out).write_text(js)
    print(js if rec.get("status") != "ok" else json.dumps(
        {k: rec[k] for k in ("arch", "shape", "mesh", "status", "compile_s",
                             "fits_hbm")}, indent=None))
    if rec.get("status") == "ok":
        print("memory_analysis:", rec["memory"])
        print("cost_analysis:", rec["cost"])
        print("collectives:", rec["collectives"])


if __name__ == "__main__":
    main()
