"""Orchestrate the full dry-run sweep: every (arch x shape x mesh) cell in a
fresh subprocess (XLA arenas are per-process), merged into one JSON.

    PYTHONPATH=src python -m repro.launch.dryrun_all \
        --out results/dryrun/all.json [--mesh single multipod] [--arch ...]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

from repro.configs.base import ARCH_IDS
from repro.configs.shapes import SHAPES


def run_cell(arch: str, shape: str, mesh: str, outdir: pathlib.Path,
             timeout: int = 3000) -> dict:
    out = outdir / f"{arch}.{shape}.{mesh}.json"
    if out.exists():
        rec = json.loads(out.read_text())
        if rec.get("status") in ("ok", "skip"):
            return rec  # cached
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(out)]
    env = dict(**__import__("os").environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        if out.exists():
            rec = json.loads(out.read_text())
        else:
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "status": "error",
                   "error": (proc.stderr or proc.stdout)[-2000:]}
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "timeout",
               "wall_s": round(time.time() - t0, 1)}
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun/all.json")
    ap.add_argument("--mesh", nargs="+", default=["single", "multipod"])
    ap.add_argument("--arch", nargs="+", default=list(ARCH_IDS))
    ap.add_argument("--shape", nargs="+", default=list(SHAPES))
    args = ap.parse_args()

    outpath = pathlib.Path(args.out)
    outdir = outpath.parent / "cells"
    outdir.mkdir(parents=True, exist_ok=True)

    records = []
    for mesh in args.mesh:
        for arch in args.arch:
            for shape in args.shape:
                rec = run_cell(arch, shape, mesh, outdir)
                records.append(rec)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f"compile={rec.get('compile_s')}s "
                             f"fits={rec.get('fits_hbm')} "
                             f"coll={rec.get('collectives', {}).get('total', 0)/1e6:.0f}MB")
                elif status == "error":
                    extra = rec.get("error", "")[:160].replace("\n", " ")
                print(f"[{len(records):3d}] {arch:22s} {shape:12s} {mesh:9s} "
                      f"{status:7s} {rec.get('wall_s', 0):7.1f}s {extra}",
                      flush=True)
                outpath.write_text(json.dumps(records, indent=1))
    ok = sum(1 for r in records if r.get("status") == "ok")
    skip = sum(1 for r in records if r.get("status") == "skip")
    bad = len(records) - ok - skip
    print(f"\ndone: {ok} ok, {skip} skip, {bad} failed -> {outpath}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
