"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero allocation) for every model input, the
parameter/optimizer trees, and serving caches."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models.model import init_cache, init_params
from repro.optim import adamw
from repro.sharding import rules


def _with_shardings(abstract, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def abstract_params(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16):
    a = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))
    return _with_shardings(a, rules.shard_params(a, mesh))


def abstract_opt_state(cfg: ModelConfig, abstract_p, mesh: Mesh,
                       ocfg: Optional[adamw.AdamWConfig] = None):
    ocfg = ocfg or adamw.AdamWConfig()
    a = jax.eval_shape(lambda p: adamw.init(ocfg, p), abstract_p)
    m = _with_shardings(a.m, rules.shard_params(a.m, mesh))
    v = _with_shardings(a.v, rules.shard_params(a.v, mesh))
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=rules.replicated(mesh))
    return adamw.OptState(m=m, v=v, step=step)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch stand-ins for a (config x shape) cell."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    specs = rules.batch_specs(mesh, b)

    def sds(shp, dtype, key):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, specs[key]))

    batch: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        tok_len = shape.seq_len
        if cfg.num_prefix_embeds:
            tok_len -= cfg.num_prefix_embeds
            batch["prefix_embeds"] = sds((b, cfg.num_prefix_embeds, cfg.d_model),
                                         jnp.bfloat16, "prefix_embeds")
        batch["tokens"] = sds((b, tok_len), jnp.int32, "tokens")
        if shape.kind == "train":
            batch["labels"] = sds((b, shape.seq_len), jnp.int32, "labels")
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.encoder_frames, cfg.d_model),
                                  jnp.bfloat16, "frames")
    else:  # decode: one new token
        batch["tokens"] = sds((b, 1), jnp.int32, "tokens")
    return batch


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                   dtype=jnp.bfloat16):
    a = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch,
                                          shape.seq_len, dtype))
    if cfg.family == "encdec":
        # cross-cache filled by prefill; stand-in matches encoder frames
        dh = cfg.resolved_head_dim
        xshape = (cfg.num_layers, shape.global_batch, cfg.encoder_frames,
                  cfg.kv_heads, dh)
        a = dict(a, xk=jax.ShapeDtypeStruct(xshape, dtype),
                 xv=jax.ShapeDtypeStruct(xshape, dtype))
    return _with_shardings(a, rules.shard_cache(a, mesh, cfg.kv_heads))
