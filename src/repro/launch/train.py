"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
        --seq-len 256 --global-batch 8 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` trains the smoke-scale config (CPU-runnable); full configs are
for real pods. ``--kernel-opt run`` invokes the Forge pipeline on the model's
kernel call-sites first and caches the tuned configs (DESIGN.md §3.1).
"""

from __future__ import annotations

import argparse
import json
import time


from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import RuntimeFlags
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kernel-opt", default="cached",
                    choices=["off", "cached", "run"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.kernel_opt == "run":
        from repro.launch.kernel_opt import optimize_arch_kernels
        optimize_arch_kernels(cfg, seq_len=args.seq_len,
                              batch=args.global_batch)

    tcfg = TrainConfig(microbatches=args.microbatches,
                       optimizer=AdamWConfig(lr=args.lr,
                                             total_steps=args.steps,
                                             warmup_steps=max(args.steps // 20, 5)))
    trainer = Trainer(cfg, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      flags=RuntimeFlags(remat=False,
                                         chunked_attention=args.seq_len > 2048),
                      tcfg=tcfg, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every)
    if args.resume:
        trainer.maybe_resume()

    t0 = time.time()
    history = trainer.train(args.steps)
    dt = time.time() - t0
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    toks = args.global_batch * args.seq_len * len(history)
    print(json.dumps({
        "arch": args.arch, "steps": len(history),
        "loss_first": round(first, 4), "loss_last": round(last, 4),
        "tokens_per_s": round(toks / dt, 1),
        "straggler_flags": trainer.straggler.flagged,
    }, indent=2))
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
