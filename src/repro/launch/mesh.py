"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init)."""

from __future__ import annotations


import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(jax.devices())} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"any jax import (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_test_mesh(shape, axes):
    """Small fake-device meshes for unit tests."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
