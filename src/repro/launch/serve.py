"""Serving driver: batched greedy generation on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.max_new + 2,
                         slots=args.slots)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, args.prompt_len),
            max_new_tokens=args.max_new))
    t0 = time.time()
    finished = engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in finished)
    print(json.dumps({
        "arch": args.arch, "finished": len(finished),
        "new_tokens": total_new, "tok_per_s": round(total_new / dt, 1),
        "sample": finished[0].generated[:8] if finished else [],
    }, indent=2))
    assert len(finished) == args.requests


if __name__ == "__main__":
    main()
