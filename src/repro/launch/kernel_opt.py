"""Forge-pipeline integration for the framework's kernel call-sites
(DESIGN.md §3.1): run the paper's optimization pipeline over the kernel
shapes an architecture actually uses, and persist the winning configs in the
tuned registry that ``kernels/ops.py`` consults.

Call-sites optimized per arch:
  * fused matmul sites: the MLP in/out projections (and MoE expert FFN dims),
    attention qkv/out projections, the logits matmul;
  * flash-attention site: (seq, seq, head_dim) from the shape spec;
  * decode-attention site: KV length from the shape spec.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig
from repro.core.engine import KernelJob
from repro.forge import Forge, ForgeConfig
from repro.hw.query import HardwareQuery
from repro.hw.specs import TPU_V5E
from repro.ir.cost import graph_flops
from repro.ir.graph import GraphBuilder
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule
from repro.kernels.ops import REGISTRY, _sig


def matmul_sites(cfg: ModelConfig, seq_len: int, batch: int
                 ) -> List[Tuple[str, int, int, int]]:
    t = batch * seq_len
    d, f = cfg.d_model, cfg.d_ff
    dh = cfg.resolved_head_dim
    sites = []
    if f:
        sites.append(("mlp_in", t, f, d))
        sites.append(("mlp_out", t, d, f))
    if cfg.num_heads:
        sites.append(("attn_qkv", t, cfg.num_heads * dh, d))
        sites.append(("attn_out", t, d, cfg.num_heads * dh))
    sites.append(("logits", t, cfg.vocab, d))
    return sites


def _gemm_program(name: str, m: int, n: int, k: int) -> KernelProgram:
    b = GraphBuilder(name)
    x = b.input((m, k), name="x")
    w = b.param((k, n), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(mm)
    sched = eager_schedule(g)
    for grp in sched.groups:
        grp.impl = "pallas_naive"
        grp.config = PallasConfig(128, 128, 32, num_stages=1)
    return KernelProgram(name, g, sched, original_flops=graph_flops(g))


def optimize_arch_kernels(cfg: ModelConfig, seq_len: int = 4096,
                          batch: int = 8, max_sites: int = 5,
                          workers: int = 1,
                          backend: str = "thread",
                          forge: Forge = None,
                          cache_path=None) -> Dict:
    # submit all call-sites as one batch: identically-shaped sites (e.g. MoE
    # experts sharing dims, or archs revisited across launches with a
    # persistent cache) replay instead of re-optimizing; differently-shaped
    # GEMM sites are family twins, so the first cold site seeds the rest
    # through the store's near-miss transfer path
    owns_forge = forge is None
    forge = forge or Forge(ForgeConfig(
        workers=workers,
        execution_backend=backend,
        cache_path=str(cache_path) if cache_path else None))
    sites = matmul_sites(cfg, seq_len, batch)[:max_sites]
    jobs = []
    for name, m, n, k in sites:
        mc = min(m, 256)
        nc = min(n, 256)
        kc = min(k, 128)
        jobs.append(KernelJob(f"{cfg.arch}:{name}",
                              _gemm_program(name, mc, nc, kc),
                              _gemm_program(name, m, n, k),
                              tags=("gemm",)))
    results = {}
    try:
        batch_results = forge.optimize_batch(jobs)
    finally:
        if owns_forge:
            # a process-backend forge keeps spawned workers warm; don't
            # leak them when the forge was created for this call only
            forge.close()
    for (name, m, n, k), eres in zip(sites, batch_results):
        res = eres.result
        grp = next((g for g in res.bench_program.schedule.groups
                    if g.impl == "pallas_blockspec" and g.config), None)
        if grp is not None:
            c = grp.config
            REGISTRY.put("matmul_fused", _sig(m, n, k, "bfloat16"), {
                "block_m": c.block_m, "block_n": c.block_n,
                "block_k": c.block_k, "group_m": c.group_m,
                "num_stages": c.num_stages})
        results[name] = {"speedup_vs_naive": round(res.speedup, 2),
                         "dims": [m, n, k], "cache_hit": eres.cache_hit,
                         "transfer": eres.transfer,
                         "seed_steps": eres.seed_steps}
    # attention sites straight from the hardware query (the pipeline's
    # gpu-specific stage delegates attention tiling to it)
    hw = HardwareQuery(TPU_V5E)
    ap = hw.get_attention_params(seq_len, seq_len, cfg.resolved_head_dim or 128)
    REGISTRY.put("flash_attention",
                 _sig(seq_len, seq_len, cfg.resolved_head_dim or 128, "bfloat16"),
                 {"block_q": ap.block_m, "block_kv": ap.block_n})
    results["flash_attention"] = {"block_q": ap.block_m, "block_kv": ap.block_n}
    return results
