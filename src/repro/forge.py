"""Public API v1 — ``repro.forge`` is the one import a driver needs.

    from repro.forge import Forge, ForgeConfig, KernelJob

    report = Forge(ForgeConfig(workers=4)).optimize_batch(jobs)

Everything here is re-exported from ``repro.core``; see
``repro.core.forge`` (facade), ``repro.core.config`` (typed config +
derived cache signatures) and ``repro.core.stages`` (stage registry /
third-party stage registration) for the implementations.
"""

from repro.core.config import ForgeConfig
from repro.core.engine import (EngineResult, EngineStats, KernelJob,
                               OptimizationEngine)
from repro.core.forge import Forge, ForgeObserver, OptimizationReport
from repro.core.pipeline import ForgePipeline, PipelineResult
from repro.core.stages import (DEFAULT_REGISTRY, StageRegistry,
                               StageRegistryError, StageSpec, register_stage)

__all__ = [
    "Forge", "ForgeConfig", "ForgeObserver", "OptimizationReport",
    "KernelJob", "EngineResult", "EngineStats",
    "StageSpec", "StageRegistry", "StageRegistryError", "DEFAULT_REGISTRY",
    "register_stage",
    # compatibility shims
    "ForgePipeline", "PipelineResult", "OptimizationEngine",
]
