"""Parameter/activation sharding rules.

Logical mapping (Megatron-style TP on the ``model`` axis, DP over
``pod``+``data``):

  * column-parallel: qkv/mlp-in/gate/router projections shard their output
    (last) dim; row-parallel ``wo`` shards its input dim (XLA inserts the
    reduce-scatter/all-reduce).
  * embeddings shard the vocab dim when it divides the axis, else d_model.
  * MoE expert weights [E, D, F] shard F (TP-within-expert — the expert count
    of the assigned MoE archs does not divide the 16-wide model axis, see
    DESIGN.md §4; the divisible-EP path lives in expert_parallel.py).
  * every rule is divisibility-guarded: a dim that does not divide the axis
    falls back to the next candidate dim or replication — this is what makes
    all 10 archs lower on the fixed production mesh.

KV caches shard batch over DP and sequence over ``model`` (split-KV decode);
recurrent states shard heads/channels over ``model``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

COL_PARALLEL = {"wq", "wk", "wv", "wi", "wg", "wx", "wz", "wb", "wc", "wdt",
                "wgate", "w_r", "w_i", "router", "conv_w"}
ROW_PARALLEL = {"wo"}
COL_BIAS = {"bq", "bk", "bv"}
REPLICATED = {"scale", "norm_scale", "dt_bias", "a_log", "d_skip", "lam",
              "b_r", "b_i", "conv_b", "pos_embed"}


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape.get(axis, 1)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
               tp_axis: str = "model", fsdp_axis: Optional[str] = "data"
               ) -> P:
    import os
    if os.environ.get("REPRO_NO_FSDP"):  # perf-iteration variant (§Perf)
        fsdp_axis = None
    tp = _axis_size(mesh, tp_axis)
    name = path[-1]
    nd = len(shape)

    def pad(spec_tail):
        """Left-pad with None for stacked leading dims (scan-over-layers)."""
        return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

    def fsdp(spec: P) -> P:
        """ZeRO/FSDP: additionally shard the first free dividing dim over the
        data axis — params/grads/moments scale with total devices, XLA
        inserts the per-use all-gather (counted by the roofline)."""
        if fsdp_axis is None or fsdp_axis not in mesh.shape:
            return spec
        fs = _axis_size(mesh, fsdp_axis)
        entries = list(spec) + [None] * (nd - len(spec))
        for i in range(nd):
            if entries[i] is None and shape[i] % fs == 0 and shape[i] >= fs:
                entries[i] = fsdp_axis
                return P(*entries)
        return spec

    if name in REPLICATED or nd == 0:
        return P()
    if name == "embed":
        v, d = shape
        if v % tp == 0:
            return fsdp(P(tp_axis, None))
        if d % tp == 0:
            return fsdp(P(None, tp_axis))
        return fsdp(P())
    if name == "unembed":
        d, v = shape
        if os.environ.get("REPRO_REPLICATE_UNEMBED"):
            # odd-vocab archs: a replicated unembed computes logits locally
            # per sequence shard (no D-contraction all-reduce) — §Perf
            return fsdp(P())
        if v % tp == 0:
            return fsdp(P(None, tp_axis))
        if d % tp == 0:
            return fsdp(P(tp_axis, None))
        return fsdp(P())
    if name in ("wi", "wg") and nd >= 3 and shape[-3] > 1:
        # MoE expert weights [.., E, D, F]. TP-within-expert only pays when
        # F/tp stays MXU-aligned; tiny-FFN MoE (granite: 512/16=32) is better
        # replicated on the model axis (§Perf iteration).
        if os.environ.get("REPRO_NO_MOE_TP") or shape[-1] // tp < 128:
            # measured (§Perf, granite): sub-128 sharded FFN width starves
            # the MXU and pays dispatch-shaped all-reduces — replicate instead
            return fsdp(P())
        base = pad([None, None, tp_axis]) if shape[-1] % tp == 0 else P()
        return fsdp(base)
    if name == "wo" and nd >= 3 and shape[-3] > 1:
        if os.environ.get("REPRO_NO_MOE_TP") or shape[-2] // tp < 128:
            return fsdp(P())
        base = pad([None, tp_axis, None]) if shape[-2] % tp == 0 else P()
        return fsdp(base)
    if name in COL_PARALLEL:
        base = pad([None, tp_axis]) if shape[-1] % tp == 0 else P()
        return fsdp(base)
    if name in ROW_PARALLEL:
        base = pad([tp_axis, None]) if shape[-2] % tp == 0 else P()
        return fsdp(base)
    if name in COL_BIAS:
        base = pad([tp_axis]) if shape[-1] % tp == 0 else P()
        return fsdp(base)
    return fsdp(P()) if nd >= 2 else P()


def shard_params(abstract_params, mesh: Mesh):
    """Map an abstract params pytree to NamedShardings."""
    def fn(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        return NamedSharding(mesh, param_spec(keys, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(fn, abstract_params)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ----------------------------------------------------------------------
# batch / cache shardings
# ----------------------------------------------------------------------

def dp_prefix_for(mesh: Mesh, dim_size: int) -> Optional[Tuple[str, ...]]:
    """Largest DP-axis prefix dividing a batch dim (None if none fits)."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        if dim_size % (prod * _axis_size(mesh, a)) == 0:
            axes.append(a)
            prod *= _axis_size(mesh, a)
    return tuple(axes) if axes else None


def batch_specs(mesh: Mesh, batch_size: Optional[int] = None) -> Dict[str, P]:
    dp = dp_axes(mesh) if batch_size is None else dp_prefix_for(mesh, batch_size)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "frames": P(dp, None, None),
        "prefix_embeds": P(dp, None, None),
    }


def shard_batch(abstract_batch, mesh: Mesh):
    out = {}
    for k, v in abstract_batch.items():
        specs = batch_specs(mesh, v.shape[0])
        out[k] = NamedSharding(mesh, specs.get(k, P()))
    return out


def cache_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
               kv_heads: int) -> P:
    """KV caches [L, B, S, Hkv, Dh]: shard B over DP (when it divides); shard
    Hkv over model when divisible, else shard the sequence dim (split-KV
    decode). Recurrent states shard their channel/head dims over model."""
    tp = _axis_size(mesh, "model")
    name = path[-1]
    nd = len(shape)

    def dp_for(dim_size: int):
        """Largest DP prefix that divides the batch dim (batch=1 cells run
        unsharded on DP — one sequence cannot split)."""
        axes = []
        prod = 1
        for a in dp_axes(mesh):
            if dim_size % (prod * _axis_size(mesh, a)) == 0:
                axes.append(a)
                prod *= _axis_size(mesh, a)
        return tuple(axes) if axes else None

    if name in ("k", "v", "xk", "xv"):
        if nd == 5:  # [L, B, S, Hkv, Dh]
            dp = dp_for(shape[1])
            if shape[3] % tp == 0:
                return P(None, dp, None, "model", None)
            if shape[2] % tp == 0:
                return P(None, dp, "model", None, None)
            return P(None, dp, None, None, None)
        if nd == 4:  # [B, S, Hkv, Dh] (hybrid per-layer window cache)
            dp = dp_for(shape[0])
            if shape[2] % tp == 0:
                return P(dp, None, "model", None)
            if shape[1] % tp == 0:
                return P(dp, "model", None, None)
            return P(dp, None, None, None)
    if name == "ssm":  # [L, B, H, P, N] or [B, H, P, N]
        if nd < 4:
            return P()
        dp = dp_for(shape[-4])
        hs = shape[-3]
        tail = ["model" if hs % tp == 0 else None, None, None]
        return P(*([None] * (nd - 4) + [dp] + tail))
    if name == "conv":  # [L?, B, K-1, C]
        dp = dp_for(shape[-3])
        c = shape[-1]
        tail = [None, "model" if c % tp == 0 else None]
        return P(*([None] * (nd - 3) + [dp] + tail))
    if name == "h":  # [B, d_rnn]
        dp = dp_for(shape[0])
        return P(dp, "model" if shape[-1] % tp == 0 else None)
    return P()


def shard_cache(abstract_cache, mesh: Mesh, kv_heads: int):
    def fn(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        return NamedSharding(mesh, cache_spec(keys, leaf.shape, mesh, kv_heads))
    return jax.tree_util.tree_map_with_path(fn, abstract_cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def gather_fsdp(tree):
    """FSDP per-use weight gather: constrain a layer's params to their
    TP-only spec (fsdp axis dropped). XLA emits the all-gather of the weight
    shards here and the reduce-scatter of their grads in the backward —
    without this, SPMD prefers to replicate the *activations* along the data
    axis instead (batch-gathered GB-scale temps). No-op outside a mesh."""
    try:
        from jax._src import mesh as mesh_lib
        env = mesh_lib.thread_resources.env.physical_mesh
        if env.empty:
            return tree
    except Exception:  # noqa: BLE001
        return tree

    def fn(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        try:
            spec = param_spec(keys, leaf.shape, env, fsdp_axis=None)
            return jax.lax.with_sharding_constraint(leaf, spec)
        except Exception:  # noqa: BLE001
            return leaf
    return jax.tree_util.tree_map_with_path(fn, tree)


def constrain_like_params(tree):
    """Constrain a param-shaped pytree (e.g. the grad tree) to the param
    sharding rules under the ambient mesh. No-op without a mesh context.
    Without this, XLA materializes full-size f32 grad/moment staging temps
    for scan-stacked weights (tens of GB/device on the large MoE archs)."""
    try:
        from jax._src import mesh as mesh_lib
        env = mesh_lib.thread_resources.env.physical_mesh
        if env.empty:
            return tree
    except Exception:  # noqa: BLE001
        return tree

    def fn(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        try:
            spec = param_spec(keys, leaf.shape, env)
            return jax.lax.with_sharding_constraint(leaf, spec)
        except Exception:  # noqa: BLE001 — hints must never break execution
            return leaf
    return jax.tree_util.tree_map_with_path(fn, tree)
