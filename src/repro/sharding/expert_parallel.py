"""Expert parallelism (EP): shard the expert dim over a mesh axis with
explicit all-to-all dispatch, via shard_map.

The assigned MoE archs (8 / 40 experts) do not divide the 16-wide production
``model`` axis, so the production mesh uses TP-within-expert (DESIGN.md §4);
this module provides the real EP path for divisible topologies and is
exercised on fake-device test meshes (tests/test_distribution.py).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.compat import shard_map


def apply_moe_ep(cfg: ModelConfig, p: Dict, x: jnp.ndarray, mesh: Mesh,
                 axis: str = "expert", capacity_factor: float = 2.0):
    """EP MoE: tokens all-to-all to their experts' shards and back.

    p["wi"/"wg"/"wo"]: [E, ...] with E % mesh.shape[axis] == 0; x: [B, S, D]
    replicated along ``axis`` (DP axes may shard B outside).
    """
    nshard = mesh.shape[axis]
    e = cfg.moe.num_experts
    assert e % nshard == 0, (e, nshard)
    e_local = e // nshard
    b, s, d = x.shape
    t = b * s

    def shard_fn(x_l, wi, wg, wo, router):
        xt = x_l.reshape(-1, d)
        flat_e, slot, keep, gates, capacity = L.moe_route(
            cfg, {"router": router}, xt, capacity_factor)
        keep_f = keep.astype(xt.dtype)[:, None]
        xr = jnp.repeat(xt, cfg.moe.top_k, axis=0) * keep_f
        # dispatch buffer laid out [E, C, D] then all-to-all over the E dim
        buf = jnp.zeros((e, capacity, d), xt.dtype).at[flat_e, slot].add(xr)
        # exchange: every shard keeps its local experts' slices from everyone
        buf = buf.reshape(nshard, e_local, capacity, d)
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                 tiled=False)
        # buf now: [e_local, nshard, capacity, d] token slices for my experts
        buf = buf.reshape(e_local, nshard * capacity, d)
        hi = jnp.einsum("ecd,edf->ecf", buf, wi)
        hg = jnp.einsum("ecd,edf->ecf", buf, wg)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hi) * hg, wo)
        # return to sender
        out = out.reshape(e_local, nshard, capacity, d)
        out = jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=0,
                                 tiled=False)
        out = out.reshape(e, capacity, d)
        gathered = out[flat_e, slot] * keep_f
        y = (gathered.reshape(t, cfg.moe.top_k, d)
             * gates.astype(out.dtype)[..., None]).sum(axis=1)
        return y.reshape(b, s, d)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P()),
        out_specs=P(),
        check_vma=False)
    return fn(x, p["wi"], p["wg"], p["wo"], p["router"])
