"""Fault-tolerant checkpointing.

Layout per step::

    <root>/step_00000042.tmp/      (written, fsynced)
    <root>/step_00000042/          (atomic rename = commit)
        manifest.json              {leaf path -> file, shape, dtype, sha256}
        <leaf>.npy ...

Guarantees:
  * atomic commit (a crash mid-write never corrupts the latest checkpoint),
  * integrity-checked restore (sha256 per leaf); corrupt checkpoints are
    quarantined (renamed ``.corrupt``) and restore falls back to the previous
    valid step,
  * **elastic**: leaves are stored unsharded; ``restore`` re-lays-out onto
    whatever mesh/sharding the caller passes — a run checkpointed on N
    devices resumes on M. (At datacenter scale the same contract is met with
    per-shard files + resharding readers; the single-file-per-leaf layout
    keeps this implementation dependency-free.)
  * retention of the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_k(p) for p in path)
        flat[key] = leaf
    return flat


def _k(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with path.open("rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, root, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> pathlib.Path:
        name = f"step_{step:08d}"
        tmp = self.root / (name + ".tmp")
        final = self.root / name
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for key, leaf in _flatten(tree).items():
            arr = np.asarray(jax.device_get(leaf))
            fname = key.replace("/", "__") + ".npy"
            true_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or true_dtype == "bfloat16":
                # ml_dtypes (bf16/fp8) round-trip as uint views
                np.save(tmp / fname, arr.view(np.uint16 if true_dtype ==
                                              "bfloat16" else np.uint8))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": true_dtype, "sha256": _sha256(tmp / fname)}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()
        return final

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in self.root.glob("step_*"):
            if d.is_dir() and not d.name.endswith((".tmp", ".corrupt")):
                try:
                    out.append(int(d.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def _verify(self, d: pathlib.Path) -> bool:
        mf = d / "manifest.json"
        if not mf.exists():
            return False
        manifest = json.loads(mf.read_text())
        for key, meta in manifest["leaves"].items():
            f = d / meta["file"]
            if not f.exists() or _sha256(f) != meta["sha256"]:
                return False
        return True

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Any:
        """Restore into the structure of ``like`` (abstract or concrete
        pytree). Falls back across corrupted checkpoints, quarantining them."""
        candidates = ([step] if step is not None else
                      list(reversed(self.steps())))
        for s in candidates:
            d = self.root / f"step_{s:08d}"
            if not self._verify(d):
                if d.exists():
                    d.rename(d.with_suffix(".corrupt"))
                continue
            manifest = json.loads((d / "manifest.json").read_text())
            flat_like = _flatten(like)
            flat_sh = _flatten(shardings) if shardings is not None else {}
            loaded = {}
            for key, ref in flat_like.items():
                meta = manifest["leaves"].get(key)
                if meta is None:
                    raise KeyError(f"checkpoint {d} missing leaf {key}")
                arr = np.load(d / meta["file"])
                if meta["dtype"] == "bfloat16":
                    import ml_dtypes
                    arr = arr.view(ml_dtypes.bfloat16)
                if tuple(arr.shape) != tuple(ref.shape):
                    raise ValueError(
                        f"{key}: checkpoint shape {arr.shape} != {ref.shape}")
                if key in flat_sh:
                    loaded[key] = jax.device_put(arr, flat_sh[key])
                else:
                    loaded[key] = jax.numpy.asarray(arr, dtype=ref.dtype)
            return _unflatten(like, loaded), s
        raise FileNotFoundError(f"no valid checkpoint under {self.root}")

    # ------------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)


def _unflatten(like, flat: Dict[str, Any]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in paths:
        key = "/".join(_k(p) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
