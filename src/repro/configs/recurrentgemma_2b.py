"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention (window 2048), pattern 2:1
(rglru, rglru, attn). [arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, kv_heads=1, d_ff=7680,
    vocab=256000, activation="gelu", glu=True,
    block_pattern=("rglru", "rglru", "attn"), window=2048,
    head_dim=256,
)
