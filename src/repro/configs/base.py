"""Model configuration schema + registry for the assigned architectures."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

ARCH_IDS = (
    "whisper-small", "grok-1-314b", "granite-moe-3b-a800m", "llava-next-34b",
    "olmo-1b", "qwen3-8b", "codeqwen1.5-7b", "qwen2-7b", "mamba2-780m",
    "recurrentgemma-2b",
)

_MODULES = {
    "whisper-small": "whisper_small",
    "grok-1-314b": "grok1_314b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "llava-next-34b": "llava_next_34b",
    "olmo-1b": "olmo_1b",
    "qwen3-8b": "qwen3_8b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen2-7b": "qwen2_7b",
    "mamba2-780m": "mamba2_780m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64          # P per head; heads = d_inner / head_dim
    expand: int = 2
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # variants
    qk_norm: bool = False
    qkv_bias: bool = False
    non_parametric_ln: bool = False        # olmo
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    activation: str = "silu"               # silu(swiglu) | gelu
    glu: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (recurrentgemma): pattern period — ("rglru","rglru","attn")
    block_pattern: Optional[Tuple[str, ...]] = None
    window: Optional[int] = None           # local attention window
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 0                # stub frontend sequence length
    # vlm
    num_prefix_embeds: int = 0             # stub patch-embedding prefix
    max_seq_len: int = 524288

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts sub-quadratically?"""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: Dict = dataclasses.asdict(self)
        kw.update(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            kv_heads=min(max(1, self.kv_heads * 4 // max(self.num_heads, 1)), 4),
            d_ff=256,
            vocab=512,
            head_dim=32,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 16) if self.encoder_frames else 0,
            num_prefix_embeds=min(self.num_prefix_embeds, 8),
            window=min(self.window, 32) if self.window else None,
            max_seq_len=2048,
        )
        if self.moe:
            kw["moe"] = MoEConfig(num_experts=min(self.moe.num_experts, 4),
                                  top_k=min(self.moe.top_k, 2))
        else:
            kw["moe"] = None
        if self.ssm:
            kw["ssm"] = SSMConfig(d_state=32, head_dim=16, expand=2, chunk=32)
        else:
            kw["ssm"] = None
        if self.block_pattern:
            kw["block_pattern"] = tuple(self.block_pattern)
            kw["num_layers"] = min(self.num_layers, 3)
        for k in ("moe", "ssm"):
            pass
        return ModelConfig(**kw)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
