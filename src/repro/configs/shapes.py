"""Assigned input shapes and (arch x shape) applicability."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicability(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs, reason). long_500k needs sub-quadratic attention — full-attention
    archs skip it (recorded, per the assignment)."""
    s = SHAPES[shape]
    if s.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention): 500k-ctx decode needs a sub-quadratic mechanism"
    return True, "ok"


def applicable_cells(cfgs: Dict[str, ModelConfig]) -> List[Tuple[str, str]]:
    cells = []
    for arch, cfg in cfgs.items():
        for shape in SHAPES:
            ok, _ = applicability(cfg, shape)
            if ok:
                cells.append((arch, shape))
    return cells
