"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres tiling frontend is a stub (patch embeddings via
input_specs, 576-token prefix). [hf:llava-hf/llava-v1.6-...; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llava-next-34b", family="dense",
    num_layers=60, d_model=7168, num_heads=56, kv_heads=8, d_ff=20480,
    vocab=64000, num_prefix_embeds=576,
)
