"""mamba2-780m [ssm]: 48L d_model=1536 attention-free d_ff=0 vocab=50280,
ssm_state=128 (SSD). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, kv_heads=0, d_ff=0,
    vocab=50280, ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128),
)
