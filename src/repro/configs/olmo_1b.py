"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304;
non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="olmo-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=16, kv_heads=16, d_ff=8192,
    vocab=50304, norm="layernorm", non_parametric_ln=True,
    activation="silu", glu=True, tie_embeddings=True,
)
