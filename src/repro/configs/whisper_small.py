"""whisper-small [audio enc-dec]: 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865; conv frontend is a stub (frame embeddings via input_specs).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, kv_heads=12, d_ff=3072,
    vocab=51865, norm="layernorm", activation="gelu", glu=False,
    qkv_bias=True, encoder_layers=12, encoder_frames=1500,
)
