"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. NOTE: the assignment text lists both
"40e top-8" and "32 experts"; we take the config field (40 experts), see
DESIGN.md. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, kv_heads=8, d_ff=512,
    vocab=49155, moe=MoEConfig(num_experts=40, top_k=8),
)
