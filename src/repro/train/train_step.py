"""Training step: loss + grad + AdamW update, with microbatch gradient
accumulation (scan) and donated buffers. Distribution comes entirely from the
in/out shardings the launcher attaches — the step itself is mesh-agnostic."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import RuntimeFlags, lm_loss
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    optimizer: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


def make_train_step(cfg: ModelConfig, flags: RuntimeFlags = RuntimeFlags(),
                    tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return lm_loss(cfg, params, batch, flags)

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        n = tcfg.microbatches

        def split(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss / n,
                    jax.tree.map(lambda a, b_: a + b_ / n, g_acc, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                               zeros), micro)
        return loss, grads

    def train_step(params, opt_state: adamw.OptState, batch: Dict
                   ) -> Tuple[Any, adamw.OptState, Dict]:
        loss, grads = grads_of(params, batch)
        from repro.sharding.rules import constrain_like_params
        grads = constrain_like_params(grads)
        params, opt_state, om = adamw.update(tcfg.optimizer, grads, opt_state,
                                             params)
        metrics = {"loss": loss, **om, "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, flags: RuntimeFlags = RuntimeFlags()):
    def eval_step(params, batch):
        return lm_loss(cfg, params, batch, flags)
    return eval_step
