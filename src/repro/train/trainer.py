"""Training loop with fault tolerance.

Features (tested in tests/test_fault_tolerance.py):
  * periodic atomic checkpoints (params + optimizer state + step) and
    auto-resume from the latest valid one — a killed run restarts
    bit-identically (deterministic data pipeline is a pure function of step),
  * corrupted-checkpoint quarantine + fallback,
  * elastic restart: checkpoints are mesh-agnostic (see ckpt/), so a resumed
    run may use a different device count,
  * straggler watchdog: per-step wall times tracked, outliers (z-score) are
    logged and counted; the hook is where a multi-host deployment would
    trigger exclusion/rebalance,
  * failure-injection hook for tests.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import RuntimeFlags, init_params
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class StragglerStats:
    times: List[float] = dataclasses.field(default_factory=list)
    flagged: int = 0

    def observe(self, dt: float, z_threshold: float = 3.0) -> bool:
        self.times.append(dt)
        if len(self.times) < 8:
            return False
        hist = np.array(self.times[-64:-1])
        mu, sd = hist.mean(), hist.std() + 1e-9
        if (dt - mu) / sd > z_threshold:
            self.flagged += 1
            log.warning("straggler step: %.3fs vs mean %.3fs (z>%.1f)",
                        dt, mu, z_threshold)
            return True
        return False


class Trainer:
    def __init__(self, cfg: ModelConfig, *, seq_len: int = 512,
                 global_batch: int = 8, flags: RuntimeFlags = RuntimeFlags(),
                 tcfg: Optional[TrainConfig] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 seed: int = 0,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.flags = flags
        self.tcfg = tcfg or TrainConfig()
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                           global_batch=global_batch),
                                model_cfg=cfg)
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.failure_hook = failure_hook
        self.straggler = StragglerStats()
        self.metrics_history: List[Dict] = []

        key = jax.random.PRNGKey(seed)
        self.params = init_params(cfg, key, jax.numpy.float32)
        self.opt_state = adamw.init(self.tcfg.optimizer, self.params)
        self.step = 0
        self._train_step = jax.jit(make_train_step(cfg, flags, self.tcfg),
                                   donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def maybe_resume(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored, step = self.ckpt.restore(state)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = step
        log.info("resumed from step %d", step)
        return True

    # ------------------------------------------------------------------
    def train(self, num_steps: int) -> List[Dict]:
        while self.step < num_steps:
            if self.failure_hook is not None:
                self.failure_hook(self.step)  # may raise to simulate a crash
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(self.step).items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(dt)
            self.step += 1
            rec = {"step": self.step, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]), "sec": dt}
            self.metrics_history.append(rec)
            if self.ckpt is not None and self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt": self.opt_state})
        if self.ckpt is not None:
            self.ckpt.save(self.step, {"params": self.params,
                                       "opt": self.opt_state})
        return self.metrics_history
