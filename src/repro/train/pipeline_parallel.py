"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

The assigned production mesh dedicates its axes to DP x TP (DESIGN.md §5), so
PP is an *optional* topology: the launcher can build a ("pipe", "data") mesh
and stage the layer stack. Implementation: shard_map over ``pipe``; each
stage holds L/P layers; microbatches stream through a lax.scan schedule with
``ppermute`` handoffs (warmup bubbles included — the classic GPipe
fill/drain), loss computed on the last stage and broadcast back.

This module is exercised by tests/test_distribution.py on fake devices; it is
deliberately self-contained (simple MLP blocks) so the schedule logic is
testable without the full model zoo.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map


def pipeline_forward(mesh: Mesh, stage_fn: Callable, num_stages: int,
                     num_microbatches: int):
    """Build fn(stage_params, x_microbatches) -> y_microbatches.

    stage_params: pytree with leading [num_stages, ...] dim (sharded on pipe);
    x_microbatches: [num_microbatches, mb, ...] (replicated; stage 0 consumes).
    stage_fn(params_stage, x) -> y applies one stage.
    """
    assert num_microbatches >= num_stages, "need >= stages microbatches"

    def per_stage(params_stage, xs):
        # params_stage: [1, ...] local slice; xs: [M, mb, ...] full stream
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        stage = jax.lax.axis_index("pipe")
        m = xs.shape[0]
        total = m + num_stages - 1
        mb_shape = xs.shape[1:]

        def step(carry, t):
            outputs, prev_out = carry
            # receive from the previous stage (stage 0 reads the stream)
            recv = jax.lax.ppermute(
                prev_out, "pipe",
                [(i, (i + 1) % num_stages) for i in range(num_stages)])
            idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(stage == 0,
                             xs[idx].astype(jnp.float32),
                             recv)
            active = (t - stage >= 0) & (t - stage < m)
            y = stage_fn(params_stage, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage commits its output at slot t - stage
            out_idx = jnp.clip(t - stage, 0, m - 1)
            commit = active & (stage == num_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(commit, y, outputs[out_idx]), out_idx, 0)
            return (outputs, y), None

        outputs0 = jnp.zeros((m,) + mb_shape, jnp.float32)
        prev0 = jnp.zeros(mb_shape, jnp.float32)
        (outputs, _), _ = jax.lax.scan(step, (outputs0, prev0),
                                       jnp.arange(total))
        # only the last stage holds real outputs; broadcast them
        outputs = jax.lax.psum(
            jnp.where(stage == num_stages - 1, outputs, jnp.zeros_like(outputs)),
            "pipe")
        return outputs

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P("pipe"), P()),
        out_specs=P(),
        check_vma=False)


def mlp_stage_fn(params_stage, x):
    """Reference stage: two-matmul MLP block (used by tests/examples)."""
    h = jnp.tanh(x @ params_stage["w1"])
    return h @ params_stage["w2"]


def init_mlp_pipeline(key, num_stages: int, d: int, dh: int):
    ks = jax.random.split(key, 2)
    return {
        "w1": jax.random.normal(ks[0], (num_stages, d, dh), jnp.float32) / d**0.5,
        "w2": jax.random.normal(ks[1], (num_stages, dh, d), jnp.float32) / dh**0.5,
    }


def reference_forward(params, x_microbatches):
    """Sequential oracle for the pipeline schedule."""
    def apply_all(x):
        for s in range(params["w1"].shape[0]):
            x = mlp_stage_fn(jax.tree.map(lambda a: a[s], params), x)
        return x
    return jax.vmap(apply_all)(x_microbatches.astype(jnp.float32))
