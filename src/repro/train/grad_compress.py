"""Cross-pod gradient compression: int8 quantization with error feedback.

The scarce resource on a multi-pod mesh is the inter-pod link; intra-pod
gradient reduction stays full-precision (XLA's automatic psum over ``data``),
while the ``pod``-axis reduction runs on int8 payloads (4x fewer bytes) with
per-leaf max-scales and an error-feedback residual so quantization noise is
carried, not lost (1-bit/qsgd-style EF-SGD, specialized to int8).

``cross_pod_mean`` is written with shard_map over the ``pod`` axis and unit-
tested on fake devices; the trainer enables it via
``TrainConfig(grad_compression="int8_ef")``-style wiring in the launcher.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression of one leaf. Returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def cross_pod_mean(grads: Any, err: Any, mesh: Mesh, axis: str = "pod"):
    """Mean-reduce grads across ``axis`` with int8 payloads + error feedback.

    grads/err are pytrees whose leaves are replicated (or equally sharded)
    along ``axis``. Returns (reduced_grads, new_err).
    """
    npods = mesh.shape[axis]

    def per_shard(g_leaf, e_leaf):
        corrected = g_leaf.astype(jnp.float32) + e_leaf
        # two-phase shared-scale quantization: exchange one scalar (pmax of
        # local scales), then the wire carries int8 payloads only.
        local_scale = jnp.max(jnp.abs(corrected)) / 127.0
        scale = jnp.maximum(jax.lax.pmax(local_scale, axis), 1e-12)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_err = corrected - q.astype(jnp.float32) * scale
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)  # int8-payload reduce
        return qsum.astype(jnp.float32) * scale / npods, new_err

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)

    out_g, out_e = [], []
    fn = shard_map(
        lambda g, e: per_shard(g, e),
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    for g, e in zip(flat_g, flat_e):
        rg, re = fn(g, e)
        out_g.append(rg)
        out_e.append(re)
    return (jax.tree_util.tree_unflatten(tree, out_g),
            jax.tree_util.tree_unflatten(tree, out_e))


def compression_ratio(grads: Any) -> float:
    """Bytes on the wire with int8+scale vs f32."""
    total_f32 = sum(l.size * 4 for l in jax.tree_util.tree_leaves(grads))
    total_q = sum(l.size * 1 + 4 for l in jax.tree_util.tree_leaves(grads))
    return total_f32 / total_q
