"""Deterministic synthetic token pipeline.

Stateless: ``batch_at(step)`` is a pure function of (seed, step), so any host
can reconstruct any batch — restart/elastic resharding never replays or skips
data. Per-host sharding slices the global batch by process index; on a real
multi-host pod each host feeds only its addressable shard
(``host_local_array_to_global_array`` in the launcher).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Markov-ish synthetic LM stream (learnable structure, deterministic)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        assert cfg.global_batch % cfg.num_hosts == 0
        self.host_batch = cfg.global_batch // cfg.num_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_id]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s, v = self.host_batch, cfg.seq_len, cfg.vocab
        # learnable stream: token_{t+1} = (31 * token_t + 17) % vocab, with 5%
        # uniform noise — next-token is a deterministic function of the
        # current token, so loss curves respond within tens of steps
        toks = np.empty((b, s), np.int64)
        toks[:, 0] = rng.integers(0, v, size=b, dtype=np.int64)
        for t in range(1, s):
            toks[:, t] = (toks[:, t - 1] * 31 + 17) % v
        noise = rng.random((b, s)) < 0.05
        toks = np.where(noise, rng.integers(0, v, size=(b, s)), toks)
        tokens = toks[:, :-1].astype(np.int32) if s > 1 else toks.astype(np.int32)
        labels = toks[:, 1:].astype(np.int32) if s > 1 else toks.astype(np.int32)
        # pad back to seq_len so shapes stay static
        tokens = np.pad(tokens, ((0, 0), (0, s - tokens.shape[1])))
        labels = np.pad(labels, ((0, 0), (0, s - labels.shape[1])),
                        constant_values=-1)
        out = {"tokens": tokens, "labels": labels}
        if self.model_cfg is not None:
            mc = self.model_cfg
            if mc.family == "encdec":
                out["frames"] = rng.standard_normal(
                    (b, mc.encoder_frames, mc.d_model)).astype(np.float32) * 0.1
            if mc.num_prefix_embeds:
                p = mc.num_prefix_embeds
                out["tokens"] = out["tokens"][:, :-p]
                out["prefix_embeds"] = rng.standard_normal(
                    (b, p, mc.d_model)).astype(np.float32) * 0.1
        return out
