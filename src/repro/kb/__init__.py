from repro.kb.loader import KnowledgeBase, Constraint, Pattern, load_default

__all__ = ["KnowledgeBase", "Constraint", "Pattern", "load_default"]
