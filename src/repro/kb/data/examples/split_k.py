"""KB example (persistent): split-K with HBM partial spills vs persistent
VMEM accumulation across the arbitrary-marked K grid dim. Expected 1.3-3x
for K >> BLOCK_K. The grid extent derives from the shape (never hardcoded);
the accumulator zero-inits on the first visit (KB: persistent_zero_init)."""

from repro.kernels.matmul_fused import matmul_fused


def after(a, b):
    # kt = cdiv(K, 512) grid steps revisit the same output block; the f32
    # scratch persists across them (dimension_semantics=(parallel, arbitrary))
    return matmul_fused(a, b, block_m=512, block_n=512, block_k=512,
                        num_stages=3)
