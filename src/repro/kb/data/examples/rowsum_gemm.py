"""KB example (discovery): sum(x @ W.T, axis=1) == x @ W.sum(axis=0).
The O(MNK) GEMM collapses to a cached O(NK) reduction + an O(MK) matvec.
Expected 10-100x. Validity: linearity of matmul over the summed axis."""

import jax.numpy as jnp


def before(x, w):
    return jnp.sum(x @ w.T, axis=1)


def after(x, w, w_sum=None):
    if w_sum is None:
        w_sum = w.sum(axis=0)      # weight statistic, computed once per load
    return x @ w_sum
