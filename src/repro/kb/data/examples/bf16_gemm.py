"""KB example (dtype): f32 GEMM -> bf16 io with f32 accumulation.
2x MXU rate + half the HBM traffic; accumulator stays f32 (KB constraint
accumulate_f32). Expected 2-4x."""

import jax.numpy as jnp
from repro.kernels.matmul_fused import matmul_fused


def after(x_f32, w_f32):
    out = matmul_fused(x_f32.astype(jnp.bfloat16), w_f32.astype(jnp.bfloat16),
                       block_m=512, block_n=512, block_k=512,
                       acc_dtype=jnp.float32, out_dtype=jnp.float32)
    return out
