"""KB example: transposed matmul (nn.Linear layout) — manual pointers +
strided reads vs BlockSpec + packed weights. Expected 2-4x."""

# BEFORE: w stored [N, K]; kernel reads it column-strided every call, flat
# grid with pl.load(ref, (pl.ds(...), pl.ds(...))) manual indexing (Mosaic
# cannot pipeline the copies).

# AFTER: pack once, BlockSpec-tile the kernel.
import jax.numpy as jnp
from repro.kernels.matmul_fused import matmul_fused


def optimized(x, w_linear_layout):
    w_packed = jnp.asarray(w_linear_layout).T   # one-time lane-contiguous pack
    return matmul_fused(x, w_packed, block_m=512, block_n=512, block_k=512)
