"""KB example (fusion): row reduction of a GEMM without materializing [M, N].
The per-n-tile partial folds into a [bm, 1] scratch; only [M] reaches HBM.
Expected 2-10x when M*N >> M*K (XLA cannot perform this fusion)."""

from repro.kernels.epilogue import EpilogueOp
from repro.kernels.matmul_fused import matmul_fused


def after(x, w):
    return matmul_fused(x, w, block_m=512, block_n=512, block_k=512,
                        epilogue=[EpilogueOp("gelu")], reduction="max")
