"""KB example (persistent + decode): single-token attention over a long KV
cache — split-KV grid with partial-softmax merge (flash decoding).
Ragged per-batch lengths handled with in-kernel masks. Expected 2-8x."""

from repro.kernels.decode_attention import decode_attention


def after(q_bhd, k_cache, v_cache, lengths):
    return decode_attention(q_bhd, k_cache, v_cache, lengths=lengths,
                            block_kv=512)
