"""KB example: GEMM + activation chain — unoptimized vs optimized.
Applied optimizations: kernel fusion, 512x512x512 bf16 tiles, GROUP_M
swizzling, f32 accumulation. Expected 2-4x."""

# ---------------- BEFORE: three launches, f32, NVIDIA-default tiles --------
# y = pl.pallas_call(matmul_kernel, grid=(M//128, N//128, K//32), ...)(x, w)
# y = y + b          # full-tensor HBM round trip
# y = jax.nn.gelu(y) # another round trip
#
# def matmul_kernel(a_ref, b_ref, o_ref):      # BLOCK_K=32 -> 1/4 MXU rate
#     ...

# ---------------- AFTER: one fused kernel -----------------------------------
from repro.kernels.epilogue import EpilogueOp
from repro.kernels.matmul_fused import matmul_fused


def optimized(x_bf16, w_bf16, bias):
    return matmul_fused(
        x_bf16, w_bf16,
        block_m=512, block_n=512, block_k=512,   # shape-aware, MXU-aligned
        group_m=8,                                # A-block stays VMEM-resident
        num_stages=2,                             # double-buffered copies
        epilogue=[EpilogueOp("bias_add", operand="bias"),
                  EpilogueOp("gelu")],            # applied to the f32 acc tile
        operands={"bias": bias})
