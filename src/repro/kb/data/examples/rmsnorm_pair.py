"""KB example: RMSNorm — three-pass jnp vs single-pass fused kernel.
Expected 1.5-3x (one read, one write)."""

from repro.kernels.rmsnorm import rmsnorm


def after(x2d, weight):
    return rmsnorm(x2d, weight, block_rows=256)  # f32 math, io dtype in/out
