"""KB example: attention — full-KV materializing kernel vs online-softmax
flash kernel with VMEM-resident running stats. Expected 2-13x (long ctx)."""

from repro.kernels.flash_attention import attention_unoptimized, flash_attention


def before(q, k, v):
    # loads the FULL K/V per q tile, materializes [bq, S] scores (spills to
    # HBM past ~16k context), single-pass softmax, f32, no pipelining
    return attention_unoptimized(q, k, v, causal=True)


def after(q, k, v):
    # online softmax: (m, l, acc) carried in VMEM scratch across KV tiles;
    # the S x S matrix never exists; tiles from the hardware query system
    return flash_attention(q, k, v, causal=True, block_q=512, block_kv=1024)
