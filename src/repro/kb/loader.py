"""Knowledge-base loader (paper §IV-D).

Scans a directory of YAML files for ``constraints`` (hard rules with severity
and wrong/correct examples) and ``patterns`` (before/after transformations
with rationale, expected speedup, applicability tags, and a machine-readable
``action`` executed by the deterministic proposers). ``examples/index.yaml``
indexes full-code before/after pairs.

Extensibility contract (same as the paper): drop a new YAML file following the
schema and it is discovered on the next run — no code changes. Stage aliases
are normalized and entries tagged to unknown stages are skipped with a
warning, so the KB can evolve independently of the pipeline code.
"""

from __future__ import annotations

import dataclasses
import hashlib
import logging
import pathlib
from typing import Any, Dict, List, Optional

import yaml

log = logging.getLogger(__name__)

# derived from the stage registry: "analysis" is the KB-only pseudo-stage
# (constraints that inform the analyzer rather than any proposer), the rest
# are the registered pipeline stages in canonical order. A live view, so a
# third-party stage registered at runtime is accepted here too.
from repro.core.stages import DEFAULT_REGISTRY as _STAGE_REGISTRY
from repro.core.stages import RegistryView as _RegistryView

STAGES = _RegistryView(lambda: ["analysis", *_STAGE_REGISTRY.names()])

_STAGE_ALIASES = {
    "memory_patterns": "memory_access",
    "memory": "memory_access",
    "dtype": "dtype_fix",
    "dtype_optimizations": "dtype_fix",
    "gpu": "gpu_specific",
    "tpu_specific": "gpu_specific",
    "tpu": "gpu_specific",
    "block_ptr": "block_pointers",
    "blockspec": "block_pointers",
    "persistent": "persistent_kernel",
    "autotune": "autotuning",
    "all": "all",
}


def _norm_stage(s: str) -> Optional[str]:
    s = str(s).strip().lower()
    s = _STAGE_ALIASES.get(s, s)
    # consult the registry live: stages registered after import still load
    if s == "all" or s == "analysis" or s in _STAGE_REGISTRY:
        return s
    return None


@dataclasses.dataclass
class Constraint:
    id: str
    severity: str             # critical | info
    stages: List[str]
    description: str
    wrong: str = ""
    correct: str = ""
    check: Dict[str, Any] = dataclasses.field(default_factory=dict)
    source_file: str = ""


@dataclasses.dataclass
class Pattern:
    id: str
    stages: List[str]
    rationale: str
    before: str = ""
    after: str = ""
    expected_speedup: str = ""
    applicability: List[str] = dataclasses.field(default_factory=list)
    action: Dict[str, Any] = dataclasses.field(default_factory=dict)
    source_file: str = ""


@dataclasses.dataclass
class Example:
    id: str
    file: str
    stages: List[str]
    optimizations: List[str]
    expected_speedup: str
    applicability: List[str]
    code: str = ""


class KnowledgeBase:
    def __init__(self, constraints: List[Constraint], patterns: List[Pattern],
                 examples: List[Example],
                 content_hash: Optional[str] = None):
        self.constraints = constraints
        self.patterns = patterns
        self.examples = examples
        self._content_hash = content_hash

    # ------------------------------------------------------------------
    def content_hash(self) -> str:
        """Stable digest of the KB's *content*. ``load`` hashes the raw
        bytes of every YAML/example file it read, so any edit — even a
        comment — produces a new hash; programmatically built KBs fall back
        to hashing their serialized entries. The optimization engine folds
        this into exact cache keys: a KB edit invalidates recorded transform
        sequences instead of replaying them forever (family-transfer seeds
        survive, since every transferred step is re-verified).

        Loaded KBs memoize the raw-bytes hash (editing the files on disk
        requires a reload anyway); programmatically built KBs recompute on
        every call so in-process mutation of constraints/patterns/examples
        is reflected immediately."""
        if self._content_hash is not None:
            return self._content_hash
        h = hashlib.sha256()
        for kind in (self.constraints, self.patterns, self.examples):
            for entry in kind:
                h.update(repr(dataclasses.astuple(entry)).encode())
        return h.hexdigest()

    # ------------------------------------------------------------------
    @classmethod
    def load(cls, root: Optional[pathlib.Path] = None) -> "KnowledgeBase":
        root = pathlib.Path(root or pathlib.Path(__file__).parent / "data")
        constraints: List[Constraint] = []
        patterns: List[Pattern] = []
        examples: List[Example] = []
        hasher = hashlib.sha256()
        for f in sorted(root.glob("*.yaml")):
            raw = f.read_text()
            hasher.update(f.name.encode())
            hasher.update(raw.encode())
            doc = yaml.safe_load(raw) or {}
            for c in doc.get("constraints", []) or []:
                stages = [s for s in map(_norm_stage, c.get("stages", []))
                          if s is not None]
                if not stages:
                    log.warning("constraint %s in %s has no known stage; skipped",
                                c.get("id"), f.name)
                    continue
                constraints.append(Constraint(
                    id=c["id"], severity=c.get("severity", "info"),
                    stages=stages, description=c.get("description", ""),
                    wrong=c.get("wrong", ""), correct=c.get("correct", ""),
                    check=c.get("check", {}) or {}, source_file=f.name))
            for p in doc.get("patterns", []) or []:
                stages = [s for s in map(_norm_stage, p.get("stages", []))
                          if s is not None]
                if not stages:
                    log.warning("pattern %s in %s has no known stage; skipped",
                                p.get("id"), f.name)
                    continue
                patterns.append(Pattern(
                    id=p["id"], stages=stages,
                    rationale=p.get("rationale", ""),
                    before=p.get("before", ""), after=p.get("after", ""),
                    expected_speedup=p.get("expected_speedup", ""),
                    applicability=list(p.get("applicability", []) or []),
                    action=p.get("action", {}) or {}, source_file=f.name))
        idx = root / "examples" / "index.yaml"
        if idx.exists():
            raw = idx.read_text()
            hasher.update(b"examples/index.yaml")
            hasher.update(raw.encode())
            doc = yaml.safe_load(raw) or {}
            for e in doc.get("examples", []) or []:
                stages = [s for s in map(_norm_stage, e.get("stages", []))
                          if s is not None]
                code_path = idx.parent / e.get("file", "")
                code = code_path.read_text() if code_path.exists() else ""
                hasher.update(e.get("file", "").encode())
                hasher.update(code.encode())
                examples.append(Example(
                    id=e["id"], file=e.get("file", ""), stages=stages,
                    optimizations=list(e.get("optimizations", []) or []),
                    expected_speedup=e.get("expected_speedup", ""),
                    applicability=list(e.get("applicability", []) or []),
                    code=code))
        return cls(constraints, patterns, examples,
                   content_hash=hasher.hexdigest())

    # ------------------------------------------------------------------
    def critical_constraints(self) -> List[Constraint]:
        return [c for c in self.constraints if c.severity == "critical"]

    def constraints_for(self, stage: str) -> List[Constraint]:
        stage = _norm_stage(stage) or stage
        return [c for c in self.constraints
                if "all" in c.stages or stage in c.stages]

    def patterns_for(self, stage: str,
                     applicability: Optional[List[str]] = None) -> List[Pattern]:
        stage = _norm_stage(stage) or stage
        out = [p for p in self.patterns if stage in p.stages]
        if applicability is not None:
            tags = set(applicability)
            out = [p for p in out
                   if not p.applicability or tags.intersection(p.applicability)
                   or "any" in p.applicability]
        return out

    def examples_for(self, stage: str) -> List[Example]:
        stage = _norm_stage(stage) or stage
        return [e for e in self.examples if stage in e.stages]

    # ------------------------------------------------------------------
    def format_for_llm(self, stage: str,
                       applicability: Optional[List[str]] = None) -> str:
        """Assemble the stage-scoped prompt section (paper §IV-D-d): critical
        constraints always included; stage patterns with before/after +
        rationale; matching full-code examples appended."""
        lines = [f"## Hardware knowledge for stage: {stage}", "",
                 "### Critical constraints (must never be violated)"]
        for c in self.critical_constraints():
            lines += [f"- [{c.id}] {c.description.strip()}"]
            if c.wrong:
                lines += [f"    WRONG:   {c.wrong.strip()}"]
            if c.correct:
                lines += [f"    CORRECT: {c.correct.strip()}"]
        stage_cs = [c for c in self.constraints_for(stage) if c.severity != "critical"]
        if stage_cs:
            lines += ["", "### Stage constraints"]
            for c in stage_cs:
                lines += [f"- [{c.id}] {c.description.strip()}"]
        pats = self.patterns_for(stage, applicability)
        if pats:
            lines += ["", "### Optimization patterns"]
            for p in pats:
                lines += [f"- [{p.id}] ({p.expected_speedup}) {p.rationale.strip()}"]
                if p.before:
                    lines += ["    BEFORE:", *("      " + l for l in p.before.splitlines())]
                if p.after:
                    lines += ["    AFTER:", *("      " + l for l in p.after.splitlines())]
        exs = self.examples_for(stage)
        if exs:
            lines += ["", "### Full-code examples"]
            for e in exs:
                lines += [f"- [{e.id}] {', '.join(e.optimizations)} "
                          f"(expected {e.expected_speedup})"]
        return "\n".join(lines)

    def stats(self) -> Dict[str, int]:
        return {
            "constraints": len(self.constraints),
            "patterns": len(self.patterns),
            "examples": len(self.examples),
            "total_entries": len(self.constraints) + len(self.patterns)
            + len(self.examples),
        }


_DEFAULT: Optional[KnowledgeBase] = None


def load_default() -> KnowledgeBase:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = KnowledgeBase.load()
    return _DEFAULT
