"""Stage registry — the single source of truth for the pipeline's stage DAG.

The paper defines Xe-Forge by its nine named stages and their dependency
constraints (§IV-A: decreasing semantic scope, restructuring before tuning).
Before this module the stage identity was stringly-typed and copy-pasted
across five modules (planner order, planner deps, the scheduler's no-planner
fallback, the proposer factory, the issue→stage routing table). Now every one
of those is *derived* from :data:`DEFAULT_REGISTRY`:

* ``planner.DEFAULT_ORDER`` / ``planner.HARD_DEPS`` — ``default_order()`` /
  ``dep_pairs()``;
* ``stage_scheduler.StageScheduler._plan``'s planner-off fallback —
  ``default_order()``;
* ``proposers.make_proposer`` — ``make_proposer()`` via each
  :class:`StageSpec`'s proposer factory;
* ``issues.ISSUE_TO_STAGE`` — the registry's live ``issue_to_stage`` mapping
  (``Issue.stage`` and dynamic issue registration go through it).

Third-party stages register without touching core modules::

    from repro.core.stages import DEFAULT_REGISTRY, StageSpec
    DEFAULT_REGISTRY.register(StageSpec(
        name="my_stage", deps=("fusion",), proposer=my_factory,
        issue_types=("my_issue",), doc="..."))

The registry is validated: duplicate names, self/unknown deps and dependency
cycles raise :class:`StageRegistryError`; ``default_order()`` is a
deterministic topological sort (Kahn's algorithm, ties broken by registration
order) so the derived default sequence is stable across runs and processes.

``python -m repro.core.stages --check`` is the CI consistency gate: it
validates the DAG and that every registered stage has a proposer factory and
at least one issue binding.

This module deliberately imports nothing from the rest of ``repro`` at module
scope — proposer factories import lazily at call time — so any core module
(issues, planner, proposers, kb.loader) can consult the registry without
creating an import cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

__all__ = ["StageSpec", "StageRegistry", "StageRegistryError",
           "DEFAULT_REGISTRY", "register_stage"]


class StageRegistryError(ValueError):
    """Invalid registry state: duplicate/unknown stage, bad dep, or cycle."""


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """Declarative description of one pipeline stage.

    ``deps`` are *hard* dependencies: every named stage must be scheduled
    before this one whenever both are active (the planner's only inviolable
    constraint — severity and LLM preferences reorder within it).
    ``proposer`` is a factory ``(kb, ctx) -> BaseProposer`` kept lazy so the
    registry can be imported without pulling in the proposer machinery.
    ``issue_types`` bind the analyzer's issue taxonomy to this stage: an
    issue routes to exactly one stage, and a stage with no active issues is
    skipped (paper §IV-A skip logic).
    """

    name: str
    deps: Tuple[str, ...] = ()
    proposer: Optional[Callable] = None
    issue_types: Tuple[str, ...] = ()
    doc: str = ""

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise StageRegistryError(f"stage name must be a non-empty "
                                     f"string, got {self.name!r}")
        if self.name in self.deps:
            raise StageRegistryError(f"stage {self.name!r} depends on itself")


class StageRegistry:
    """Validated, ordered collection of :class:`StageSpec`.

    Registration order is meaningful: it is the tiebreak for the
    deterministic topological ``default_order()``, so registering the paper's
    nine stages in their canonical sequence reproduces the paper's default
    order exactly.
    """

    def __init__(self):
        self._specs: Dict[str, StageSpec] = {}      # insertion-ordered
        self._issue_to_stage: Dict[str, str] = {}

    # -- registration ---------------------------------------------------
    def register(self, spec: StageSpec, replace: bool = False) -> StageSpec:
        if not replace and spec.name in self._specs:
            raise StageRegistryError(f"stage {spec.name!r} already "
                                     f"registered (pass replace=True)")
        if replace and spec.name in self._specs:
            # drop the old spec's issue bindings; re-added below
            for t in self._specs[spec.name].issue_types:
                self._issue_to_stage.pop(t, None)
        for t in spec.issue_types:
            owner = self._issue_to_stage.get(t)
            if owner is not None and owner != spec.name:
                raise StageRegistryError(
                    f"issue type {t!r} is already bound to stage {owner!r}")
        self._specs[spec.name] = spec
        for t in spec.issue_types:
            self._issue_to_stage[t] = spec.name
        return spec

    def bind_issue(self, issue_type: str, stage: str):
        """Route an issue type to a registered stage (dynamic registration:
        new KB files can introduce issue types without code changes)."""
        if stage not in self._specs:
            raise StageRegistryError(
                f"unknown stage {stage!r}; known: {list(self._specs)}")
        self._issue_to_stage[issue_type] = stage

    # -- lookups --------------------------------------------------------
    def get(self, name: str) -> StageSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise StageRegistryError(
                f"unknown stage {name!r}; known: {list(self._specs)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self) -> Iterator[StageSpec]:
        return iter(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    def names(self) -> Tuple[str, ...]:
        """Stage names in registration order."""
        return tuple(self._specs)

    @property
    def issue_to_stage(self) -> Dict[str, str]:
        """The *live* issue→stage routing dict. ``repro.core.issues`` exposes
        this same object as ``ISSUE_TO_STAGE``, so dynamic bindings are
        visible everywhere immediately."""
        return self._issue_to_stage

    def stage_for_issue(self, issue_type: str) -> str:
        try:
            return self._issue_to_stage[issue_type]
        except KeyError:
            raise StageRegistryError(
                f"issue type {issue_type!r} is not bound to any stage") from None

    def dep_pairs(self) -> List[Tuple[str, str]]:
        """Hard constraints as ``(before, after)`` pairs (planner form)."""
        return [(dep, spec.name) for spec in self._specs.values()
                for dep in spec.deps]

    # -- validation + ordering ------------------------------------------
    def validate(self):
        """Raise :class:`StageRegistryError` on unknown deps or cycles."""
        for spec in self._specs.values():
            for dep in spec.deps:
                if dep not in self._specs:
                    raise StageRegistryError(
                        f"stage {spec.name!r} depends on unknown stage "
                        f"{dep!r}")
        self.default_order()   # raises on cycles

    def default_order(self) -> List[str]:
        """Deterministic topological order of all registered stages: Kahn's
        algorithm with ties broken by registration order. With the paper's
        nine stages registered canonically this equals the paper's default
        sequence."""
        remaining = dict(self._specs)
        order: List[str] = []
        while remaining:
            ready = [n for n, s in remaining.items()
                     if not any(d in remaining for d in s.deps)]
            if not ready:
                raise StageRegistryError(
                    f"dependency cycle among stages: {sorted(remaining)}")
            nxt = ready[0]                 # registration-order tiebreak
            order.append(nxt)
            del remaining[nxt]
        return order

    # -- factories ------------------------------------------------------
    def make_proposer(self, stage: str, kb, ctx):
        """Instantiate the stage's proposer via its registered factory."""
        spec = self.get(stage)
        if spec.proposer is None:
            raise StageRegistryError(f"stage {stage!r} has no proposer "
                                     f"factory registered")
        return spec.proposer(kb, ctx)

    # -- CI gate --------------------------------------------------------
    def check(self) -> List[str]:
        """Full consistency check; returns a list of problems (empty = OK):
        DAG validity, a proposer factory per stage, ≥1 issue binding per
        stage, and no issue routed to an unregistered stage."""
        problems: List[str] = []
        try:
            self.validate()
        except StageRegistryError as e:
            problems.append(str(e))
        for spec in self._specs.values():
            if spec.proposer is None:
                problems.append(f"stage {spec.name!r} has no proposer factory")
            if not any(s == spec.name for s in self._issue_to_stage.values()):
                problems.append(f"stage {spec.name!r} has no issue binding "
                                f"(it could never be scheduled)")
        for issue_type, stage in self._issue_to_stage.items():
            if stage not in self._specs:
                problems.append(f"issue type {issue_type!r} routes to "
                                f"unregistered stage {stage!r}")
        return problems


class RegistryView(list):
    """A list-like *live view* over a registry-derived sequence.

    ``planner.DEFAULT_ORDER``/``HARD_DEPS`` and ``kb.loader.STAGES`` are
    module-level names that predate the registry; snapshot lists would go
    stale the moment a third-party stage registers, and module ``__getattr__``
    would not help re-exports that bound the object at import time. The view
    recomputes from the registry on every read, while still comparing and
    iterating like the lists/tuples existing callers expect. It is seeded at
    construction so even unproxied ``list`` methods see registration-time
    content rather than nothing."""

    def __init__(self, compute):
        self._compute = compute
        super().__init__(compute())

    def _refresh(self):
        self[:] = self._compute()

    def __iter__(self):
        self._refresh()
        return super().__iter__()

    def __reversed__(self):
        self._refresh()
        return super().__reversed__()

    def __len__(self):
        self._refresh()
        return super().__len__()

    def __getitem__(self, i):
        self._refresh()
        return super().__getitem__(i)

    def __contains__(self, x):
        self._refresh()
        return super().__contains__(x)

    def __eq__(self, other):
        self._refresh()
        return list(self) == other

    def __ne__(self, other):
        return not self.__eq__(other)

    __hash__ = None

    def __add__(self, other):
        self._refresh()
        return list(self) + list(other)

    def __radd__(self, other):
        self._refresh()
        return list(other) + list(self)

    def index(self, *a, **kw):
        self._refresh()
        return super().index(*a, **kw)

    def count(self, x):
        self._refresh()
        return super().count(x)

    def copy(self):
        self._refresh()
        return list(self)

    def __repr__(self):
        self._refresh()
        return super().__repr__()

    def __reduce__(self):
        # pickle as a plain snapshot list (the compute closure isn't
        # picklable, and a worker process has its own registry anyway)
        self._refresh()
        return (list, (list(self),))


# ===========================================================================
# default registry: the paper's nine stages (§IV-A)
# ===========================================================================

def _rewrite_factory(stage: str) -> Callable:
    def factory(kb, ctx, _stage=stage):
        from repro.core.proposers import RewriteProposer
        return RewriteProposer(kb, ctx, _stage)
    return factory


def _class_factory(class_name: str) -> Callable:
    def factory(kb, ctx, _cls=class_name):
        from repro.core import proposers
        return getattr(proposers, _cls)(kb, ctx)
    return factory


DEFAULT_REGISTRY = StageRegistry()


def register_stage(spec: StageSpec, replace: bool = False) -> StageSpec:
    """Register into the process-wide default registry (module-level
    convenience mirroring ``issues.register_issue_type``)."""
    return DEFAULT_REGISTRY.register(spec, replace=replace)


for _spec in (
    StageSpec(
        name="algorithmic", deps=(),
        proposer=_rewrite_factory("algorithmic"),
        issue_types=("redundant_computation", "gemm_feeding_reduction",
                     "foldable_scalar_epilogue", "bn_after_conv",
                     "duplicated_subexpression", "serial_accumulation",
                     "materialized_transpose", "mean_uncanonicalized"),
        doc="Graph-level algebraic restructuring: eliminate redundant "
            "computation, fold epilogues, canonicalize reductions."),
    StageSpec(
        name="discovery", deps=(),
        proposer=_rewrite_factory("discovery"),
        issue_types=("open_ended",),
        doc="Open-ended optimization discovery: KB-guided rewrites beyond "
            "the fixed issue taxonomy (must carry a detailed proposal)."),
    StageSpec(
        name="dtype_fix", deps=("algorithmic", "discovery"),
        proposer=_class_factory("DtypeProposer"),
        issue_types=("dtype_float64", "dtype_precision",
                     "dtype_input_conversion"),
        doc="Precision repair: demote f64, pick mixed-precision compute "
            "dtypes that the verifier's tolerances accept."),
    StageSpec(
        name="fusion", deps=("algorithmic", "discovery", "dtype_fix"),
        proposer=_class_factory("FusionProposer"),
        issue_types=("unfused_kernels", "unfused_elementwise_chain",
                     "unfused_reduction_epilogue", "fusion_noop",
                     "fusion_register_pressure", "fusion_replaces_vendor"),
        doc="Kernel fusion: merge launch-bound elementwise chains and "
            "reduction epilogues into their producers."),
    StageSpec(
        name="memory_access", deps=(),
        proposer=_class_factory("MemoryProposer"),
        issue_types=("uncoalesced_access", "missing_boundary_check",
                     "device_host_sync", "non_contiguous_input",
                     "long_liveness", "high_register_pressure",
                     "suboptimal_conv_layout"),
        doc="Memory-access repair: coalescing, layout, liveness, "
            "host-sync elimination."),
    StageSpec(
        name="block_pointers", deps=("memory_access",),
        proposer=_class_factory("BlockPointerProposer"),
        issue_types=("manual_pointer_arithmetic", "block_ptr_boundary_wrong",
                     "block_ptr_multiple_of_misuse"),
        doc="Block-pointer (BlockSpec) form: replace manual pointer "
            "arithmetic with bounds-checked block descriptors."),
    StageSpec(
        name="persistent_kernel", deps=(),
        proposer=_class_factory("PersistentProposer"),
        issue_types=("missing_persistent", "persistent_num_progs_hardcoded"),
        doc="Persistent-kernel conversion: grid-resident workers instead of "
            "one program instance per tile."),
    StageSpec(
        name="gpu_specific", deps=("fusion", "block_pointers"),
        proposer=_class_factory("GpuSpecificProposer"),
        issue_types=("suboptimal_tile_size", "misaligned_block_shape",
                     "no_swizzling", "missing_pipeline_stages",
                     "missing_dimension_semantics", "repack_in_forward",
                     "missing_packed_transpose", "serialized_n_tiles",
                     "sigmoid_slow_exp", "bf16_accumulator"),
        doc="Target-specific tuning: tile alignment, swizzling, pipeline "
            "stages, packed layouts (the hardware-query-driven stage)."),
    StageSpec(
        name="autotuning", deps=("gpu_specific",),
        proposer=_class_factory("AutotuneProposer"),
        issue_types=("missing_autotune",),
        doc="Curated-grid autotuning over the surviving schedule's block "
            "configs (always last: tunes whatever structure won)."),
):
    DEFAULT_REGISTRY.register(_spec)

DEFAULT_REGISTRY.validate()


# ===========================================================================
# CLI: the CI consistency gate
# ===========================================================================

def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.stages",
        description="Stage-registry consistency gate.")
    ap.add_argument("--check", action="store_true",
                    help="validate the stage DAG and that every stage has a "
                         "proposer factory and at least one issue binding")
    args = ap.parse_args(argv)
    # operate on the canonical instance even when run as __main__
    from repro.core.stages import DEFAULT_REGISTRY as registry
    if not args.check:
        ap.print_help()
        return 0
    problems = registry.check()
    if problems:
        for p in problems:
            print(f"FAIL: {p}")
        return 1
    order = registry.default_order()
    print(f"OK: {len(registry)} stages, "
          f"{len(registry.dep_pairs())} hard deps, "
          f"{len(registry.issue_to_stage)} issue bindings")
    print(f"topo order: {' -> '.join(order)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
