"""Unified observer API for Forge optimization runs.

Historically Forge grew four ad-hoc callback surfaces:

- ``on_stage_complete(job_name, record)`` observer method (per stage),
- ``on_job_complete(result)`` observer method (per finished job),
- ``on_transfer(result)`` observer method (family-transfer seeds),
- the index-keyed ``on_stage=(index, job_name, record)`` batch kwarg
  added for the service's per-job SSE sinks.

This module replaces all four with a single typed protocol:
:class:`ForgeObserver` with default-no-op methods taking frozen event
dataclasses (:class:`StageEvent`, :class:`JobEvent`,
:class:`TransferEvent`).  The old surfaces keep working unchanged —
:func:`as_observer` wraps any legacy object (anything exposing the old
method names) in an adapter, and :class:`CallbackObserver` adapts the
old loose-callback kwargs — so existing drivers migrate without any
behavior change.  Event *content* and *ordering* are identical across
old and new surfaces (see ``tests/test_remote_fleet.py``).

Dispatch is serialized by the engine (one event at a time under a
lock), so observers never need their own locking.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence

__all__ = [
    "StageEvent",
    "JobEvent",
    "TransferEvent",
    "ForgeObserver",
    "CallbackObserver",
    "FanOutObserver",
    "as_observer",
]


@dataclasses.dataclass(frozen=True)
class StageEvent:
    """One pipeline stage finished for one job.

    ``index`` is the job's position within the current batch (``None``
    when the stage fired outside a batch context, e.g. a direct
    ``pipeline.optimize`` call routed through an adapter).
    """

    job_name: str
    record: Any  # StageRecord
    index: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class JobEvent:
    """One job finished (``result`` is the EngineResult)."""

    result: Any


@dataclasses.dataclass(frozen=True)
class TransferEvent:
    """A finished job was seeded by a family-fingerprint transfer."""

    result: Any


class ForgeObserver:
    """Typed observer protocol: subclass and override what you need.

    All methods are no-ops by default.  Events arrive serialized (the
    engine holds a dispatch lock), in deterministic order: every
    :meth:`on_stage` for a job precedes its :meth:`on_job`;
    :meth:`on_seed_transfer` (if the job was transfer-seeded) follows
    immediately after that job's :meth:`on_job`.

    Legacy observers — objects exposing ``on_stage_complete(name,
    record)`` / ``on_job_complete(result)`` / ``on_transfer(result)`` —
    are still accepted everywhere an observer is and are adapted via
    :func:`as_observer`; they see the same events in the same order.
    """

    def on_stage(self, event: StageEvent) -> None:  # pragma: no cover
        """A stage completed for one job."""

    def on_job(self, event: JobEvent) -> None:  # pragma: no cover
        """A job completed (cache hit, replay, or fresh optimization)."""

    def on_seed_transfer(self, event: TransferEvent) -> None:  # pragma: no cover
        """A completed job had been seeded from a family neighbor."""


def _wants(obj: Any, name: str) -> bool:
    """True when *obj* provides a real (non-default) new-protocol method."""
    fn = getattr(obj, name, None)
    if not callable(fn):
        return False
    if isinstance(obj, ForgeObserver):
        return getattr(type(obj), name, None) is not getattr(ForgeObserver, name)
    return True


class _Adapter(ForgeObserver):
    """Route events to whichever surface (new or legacy) *obj* exposes.

    New-protocol methods win when both are present, so a class can
    migrate one method at a time.
    """

    def __init__(self, obj: Any):
        self._obj = obj
        self._stage_new = _wants(obj, "on_stage")
        self._job_new = _wants(obj, "on_job")
        self._transfer_new = _wants(obj, "on_seed_transfer")
        self._stage_old = callable(getattr(obj, "on_stage_complete", None))
        self._job_old = callable(getattr(obj, "on_job_complete", None))
        self._transfer_old = callable(getattr(obj, "on_transfer", None))

    def on_stage(self, event: StageEvent) -> None:
        if self._stage_new:
            self._obj.on_stage(event)
        elif self._stage_old:
            self._obj.on_stage_complete(event.job_name, event.record)

    def on_job(self, event: JobEvent) -> None:
        if self._job_new:
            self._obj.on_job(event)
        elif self._job_old:
            self._obj.on_job_complete(event.result)

    def on_seed_transfer(self, event: TransferEvent) -> None:
        if self._transfer_new:
            self._obj.on_seed_transfer(event)
        elif self._transfer_old:
            self._obj.on_transfer(event.result)


class CallbackObserver(ForgeObserver):
    """Adapter from the deprecated loose-callback kwargs.

    ``on_stage_indexed`` is the batch-scoped ``(index, job_name,
    record)`` callback (the service's original ``on_stage=`` kwarg).
    """

    def __init__(
        self,
        on_stage_complete: Optional[Callable[[str, Any], None]] = None,
        on_job_complete: Optional[Callable[[Any], None]] = None,
        on_transfer: Optional[Callable[[Any], None]] = None,
        on_stage_indexed: Optional[Callable[[int, str, Any], None]] = None,
    ):
        self._stage = on_stage_complete
        self._job = on_job_complete
        self._transfer = on_transfer
        self._stage_indexed = on_stage_indexed

    def on_stage(self, event: StageEvent) -> None:
        if self._stage is not None:
            self._stage(event.job_name, event.record)
        if self._stage_indexed is not None and event.index is not None:
            self._stage_indexed(event.index, event.job_name, event.record)

    def on_job(self, event: JobEvent) -> None:
        if self._job is not None:
            self._job(event.result)

    def on_seed_transfer(self, event: TransferEvent) -> None:
        if self._transfer is not None:
            self._transfer(event.result)


class FanOutObserver(ForgeObserver):
    """Dispatch every event to an ordered list of observers.

    Preserves the historical Forge ordering for multi-observer runs:
    each event reaches every observer (in registration order) before
    the next event is dispatched.
    """

    def __init__(self, observers: Sequence[ForgeObserver] = ()):
        self._observers: List[ForgeObserver] = list(observers)

    def add(self, observer: ForgeObserver) -> None:
        self._observers.append(observer)

    def __len__(self) -> int:
        return len(self._observers)

    def on_stage(self, event: StageEvent) -> None:
        for obs in self._observers:
            obs.on_stage(event)

    def on_job(self, event: JobEvent) -> None:
        for obs in self._observers:
            obs.on_job(event)

    def on_seed_transfer(self, event: TransferEvent) -> None:
        for obs in self._observers:
            obs.on_seed_transfer(event)


def as_observer(obj: Any) -> Optional[ForgeObserver]:
    """Coerce *obj* into a :class:`ForgeObserver` (``None`` passes through).

    Accepts new-protocol observers, legacy observers (old method
    names), and mixed objects; always wraps in :class:`_Adapter` so
    legacy names keep firing even on ``ForgeObserver`` subclasses that
    only define the old surface.
    """
    if obj is None:
        return None
    if isinstance(obj, (_Adapter, CallbackObserver, FanOutObserver)):
        return obj
    return _Adapter(obj)
