"""Deterministic fault injection for the durable Forge stack.

Real crashes are the one failure mode CI can't schedule: a worker dies
when the OOM killer feels like it, a dispatcher box reboots mid-wave,
a disk tears the last journal record whenever the power goes. This
module makes every one of those paths a *deterministic, reproducible*
event instead: a :class:`FaultPlan` names the exact injection point
(drop event frame N, kill the worker after K jobs, crash the service
dispatcher before/after its journal commit, tear journal record M) and
the sites that honor it — the service dispatcher in
:mod:`repro.serve.service`, the fleet coordinator in
:mod:`repro.core.fleet`, the worker loop in
:mod:`repro.core.remote_worker`, and :class:`repro.core.journal.Journal`
— fire it at precisely that point, every run, so the recovery paths the
chaos gate asserts on are exercised on purpose rather than observed by
luck.

Injected crashes raise :class:`InjectedCrash`, which every normal
``except Exception`` failure handler in the stack deliberately re-raises
instead of absorbing: an injected crash must *kill* its thread the way a
process death would, not be laundered into a tidy "job failed" record.

The plan is JSON-codable (:meth:`FaultPlan.to_json` /
:meth:`FaultPlan.from_json`) so it threads through every boundary the
faults target: a ``ForgeConfig.fault_spec`` string reaches the fleet
coordinator inside a remote-backend engine, and the coordinator forwards
the plan to the spawned worker whose index matches
``worker_index`` via the ``forge-worker --fault-plan`` flag
(generalizing the older ``--die-after``).

:func:`deterministic_backoff` also lives here: the capped-exponential,
sha256-jittered sleep schedule introduced for ``ForgeClient.wait`` —
now shared by worker ``--reconnect``, coordinator auto-respawn, and the
client's 429 retry, so every retry loop in the stack desynchronizes
identically and reproducibly (no ``random`` anywhere).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Any, Dict, Optional

__all__ = ["FaultPlan", "InjectedCrash", "DISPATCHER_CRASH_POINTS",
           "deterministic_backoff"]

#: Where the service dispatcher can be crashed relative to its terminal
#: journal commit: "before-journal" leaves the wave's jobs with no
#: completion record (recovery re-runs them), "after-journal" commits
#: the completions first (recovery restores them as done).
DISPATCHER_CRASH_POINTS = ("before-journal", "after-journal")


class InjectedCrash(RuntimeError):
    """A FaultPlan injection point fired. Simulates process death: normal
    failure handlers must re-raise it, never convert it into a handled
    job/wave failure."""


def deterministic_backoff(key: str, attempt: int, base_s: float = 0.05,
                          cap_s: float = 2.0) -> float:
    """Capped exponential backoff with *deterministic* jitter.

    The jitter fraction is derived from ``sha256(key:attempt)`` — no
    ``random``, so a given (key, attempt) always sleeps the same amount
    (reproducible tests, debuggable traces) while distinct keys retrying
    against the same peer desynchronize instead of stampeding in
    lockstep. Sleeps grow ``base_s * 2^attempt`` and are scaled into
    ``[0.5, 1.0) ×`` that, capped at ``cap_s``.
    """
    raw = min(cap_s, base_s * (2.0 ** attempt))
    digest = hashlib.sha256(f"{key}:{attempt}".encode()).digest()
    frac = int.from_bytes(digest[:8], "big") / 2.0 ** 64
    return raw * (0.5 + 0.5 * frac)


@dataclasses.dataclass
class FaultPlan:
    """One deterministic set of injection points. Counters are internal
    and lock-guarded, so concurrent sites (journal appends from handler
    threads, completions from the coordinator run loop) see a single
    consistent firing sequence. ``fired`` records which sites actually
    triggered — the chaos gate asserts on it so a green run can't mean
    "the fault never happened".

    All triggers are 1-based counts, so a plan is reproducible from its
    JSON form alone; ``seed`` keys the deterministic backoff jitter of
    the paths the plan disturbs (respawn/reconnect) so two chaos runs
    with different seeds explore different — but individually exact —
    retry timings.
    """

    seed: int = 0
    # -- worker-side (applied to the spawned worker at worker_index) ---
    #: die with ``os._exit(DIE_EXIT_CODE)`` upon receiving job task
    #: K+1 (keys tasks don't count) — exactly ``--die-after K``.
    kill_worker_after_jobs: Optional[int] = None
    #: sever the socket instead of sending outbound *event* frame N
    #: (1-based; pongs don't count — ping cadence is timing-dependent),
    #: then exit with ``DROP_EXIT_CODE``. The coordinator sees EOF,
    #: marks the worker lost, and must re-dispatch its in-flight task.
    drop_frame_after: Optional[int] = None
    #: which coordinator-spawned worker receives the worker faults.
    worker_index: int = 0
    # -- service dispatcher --------------------------------------------
    #: crash the ForgeService dispatcher on wave N (1-based) at
    #: ``crash_dispatcher_point`` relative to the terminal journal
    #: commit of that wave.
    crash_dispatcher_wave: Optional[int] = None
    crash_dispatcher_point: str = "before-journal"
    # -- fleet coordinator ---------------------------------------------
    #: crash the coordinator run loop right after journaling its Nth
    #: merge-once completion (1-based, counted across runs — keys waves
    #: included), leaving dispatched-but-incomplete tasks in the journal.
    crash_coordinator_after_completions: Optional[int] = None
    # -- journal --------------------------------------------------------
    #: tear journal append N (1-based): write only half the record's
    #: bytes, then raise InjectedCrash — the torn-tail tolerance path.
    torn_write_record: Optional[int] = None

    def __post_init__(self):
        if self.crash_dispatcher_point not in DISPATCHER_CRASH_POINTS:
            raise ValueError(
                f"crash_dispatcher_point must be one of "
                f"{DISPATCHER_CRASH_POINTS}, "
                f"got {self.crash_dispatcher_point!r}")
        for name in ("kill_worker_after_jobs", "drop_frame_after",
                     "crash_dispatcher_wave",
                     "crash_coordinator_after_completions",
                     "torn_write_record"):
            v = getattr(self, name)
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0, got {v}")
        if self.worker_index < 0:
            raise ValueError("worker_index must be >= 0")
        # runtime state (not part of the plan's identity/codec)
        self._lock = threading.Lock()
        self._frames = 0
        self._waves = 0
        self._completions = 0
        self._records = 0
        self.fired: Dict[str, int] = {}

    # -- firing record --------------------------------------------------
    def _fire(self, site: str) -> None:
        self.fired[site] = self.fired.get(site, 0) + 1

    # -- worker ----------------------------------------------------------
    def worker_should_die(self, jobs_seen: int) -> bool:
        """``--die-after`` semantics: die upon receiving job task K+1."""
        if (self.kill_worker_after_jobs is not None
                and jobs_seen >= self.kill_worker_after_jobs):
            with self._lock:
                self._fire("kill_worker")
            return True
        return False

    def take_event_frame(self) -> bool:
        """Count one outbound event frame; True = sever the socket now
        instead of sending it."""
        if self.drop_frame_after is None:
            return False
        with self._lock:
            self._frames += 1
            if self._frames == self.drop_frame_after:
                self._fire("drop_frame")
                return True
        return False

    # -- service dispatcher ----------------------------------------------
    def next_wave(self) -> int:
        """Register one dispatcher wave; returns its 1-based number."""
        with self._lock:
            self._waves += 1
            return self._waves

    def should_crash_dispatcher(self, wave_no: int, point: str) -> bool:
        if (self.crash_dispatcher_wave is not None
                and wave_no == self.crash_dispatcher_wave
                and point == self.crash_dispatcher_point):
            with self._lock:
                self._fire(f"crash_dispatcher:{point}")
            return True
        return False

    # -- coordinator ------------------------------------------------------
    def take_completion(self) -> bool:
        """Count one journaled merge-once completion; True = crash now."""
        if self.crash_coordinator_after_completions is None:
            return False
        with self._lock:
            self._completions += 1
            if (self._completions
                    == self.crash_coordinator_after_completions):
                self._fire("crash_coordinator")
                return True
        return False

    # -- journal ----------------------------------------------------------
    def take_record(self) -> bool:
        """Count one journal append; True = tear this record's write."""
        if self.torn_write_record is None:
            return False
        with self._lock:
            self._records += 1
            if self._records == self.torn_write_record:
                self._fire("torn_write")
                return True
        return False

    # -- codec ------------------------------------------------------------
    def has_worker_faults(self) -> bool:
        return (self.kill_worker_after_jobs is not None
                or self.drop_frame_after is not None)

    def to_dict(self) -> Dict[str, Any]:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultPlan fields: {sorted(unknown)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        if not isinstance(d, dict):
            raise ValueError("FaultPlan JSON must be an object")
        return cls.from_dict(d)
