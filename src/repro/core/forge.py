"""The Forge facade — the public v1 entry point for kernel optimization.

Every driver used to wire the engine differently: build a ``ForgePipeline``
with one kwarg list, wrap it in an ``OptimizationEngine`` with another, then
thread prints and counters through by hand. The facade collapses that to::

    from repro.forge import Forge, ForgeConfig, KernelJob

    forge = Forge(ForgeConfig(workers=4, cache_path="results/store.json"))
    report = forge.optimize_batch(jobs)        # -> OptimizationReport
    print(report.summary())

Observers replace the driver-specific print/stat plumbing: attach a
:class:`~repro.core.observers.ForgeObserver` (typed events —
:class:`StageEvent` / :class:`JobEvent` / :class:`TransferEvent`, all
methods default no-op) or any legacy object exposing the old
``on_stage_complete(job_name, record)`` / ``on_job_complete(result)`` /
``on_transfer(result)`` names — :func:`repro.core.observers.as_observer`
adapts either shape with identical event content and ordering. Events
fire as the fleet engine makes progress, serialized under a lock so
observers need not be thread-safe even with ``workers > 1``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.core.config import ForgeConfig
from repro.core.engine import (EngineResult, EngineStats, KernelJob,
                               OptimizationEngine, VerifyStats)
from repro.core.history import History
from repro.core.llm import LLMClient
from repro.core.observers import (CallbackObserver, FanOutObserver,
                                  ForgeObserver, JobEvent, StageEvent,
                                  TransferEvent, as_observer)
from repro.core.pipeline import ForgePipeline
from repro.core.result_store import ResultStore
from repro.ir.schedule import KernelProgram
from repro.kb.loader import KnowledgeBase

__all__ = ["Forge", "ForgeObserver", "OptimizationReport",
           "StageEvent", "JobEvent", "TransferEvent", "CallbackObserver",
           "as_observer"]


@dataclasses.dataclass
class OptimizationReport:
    """Typed result of a :meth:`Forge.optimize` / :meth:`optimize_batch`
    call: per-job engine results (submission order), an engine-stats
    snapshot, and the config that produced them."""

    results: List[EngineResult]
    stats: EngineStats
    config: ForgeConfig
    # verify-layer counters for the same jobs (session memo hits/misses,
    # shared-cache hits, planner dedup); separate from ``stats`` because
    # shared-hit counts are backend-dependent while EngineStats is asserted
    # backend-identical (see engine.VerifyStats). None when fastpath is off.
    verify: Optional[VerifyStats] = None

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i) -> EngineResult:
        return self.results[i]

    @property
    def result(self) -> EngineResult:
        """The single result of a one-job ``optimize`` call."""
        if len(self.results) != 1:
            raise ValueError(f"report holds {len(self.results)} results; "
                             f"use .results / iteration")
        return self.results[0]

    # -- aggregates ------------------------------------------------------
    @property
    def speedups(self) -> Dict[str, float]:
        return {r.job.name: r.result.speedup for r in self.results}

    @property
    def geomean_speedup(self) -> float:
        vals = [max(r.result.speedup, 1e-9) for r in self.results]
        return (math.exp(sum(math.log(v) for v in vals) / len(vals))
                if vals else 0.0)

    @property
    def cache_hits(self) -> int:
        return sum(1 for r in self.results if r.cache_hit)

    @property
    def transfers(self) -> int:
        return sum(1 for r in self.results if r.transfer)

    @classmethod
    def from_result(cls, result: EngineResult,
                    config: ForgeConfig) -> "OptimizationReport":
        """Rebuild the single-job report for one :class:`EngineResult` —
        exactly the report a one-job :meth:`Forge.optimize` call would have
        produced for the same outcome. The stats mirror
        ``OptimizationEngine._apply_outcome`` field for field, and the
        verify stats are the job's own session counters (a one-job batch
        triggers no planner activity, so nothing is lost). The Forge
        service uses this to hand every queued submission its own report
        even when the dispatcher batched it into a multi-job wave."""
        hit = bool(result.cache_hit)
        stats = EngineStats(
            jobs=1,
            cache_hits=int(hit),
            cache_misses=int(not hit),
            replay_fallbacks=int(result.replay_fallback),
            family_transfers=int(not hit and result.had_seed
                                 and result.transfer),
            transfer_fallbacks=int(not hit and result.had_seed
                                   and not result.transfer))
        verify = None
        if config.verify_fastpath != "off":
            verify = VerifyStats()
            verify.add_session(result.verify or {})
        return cls(results=[result], stats=stats, config=config,
                   verify=verify)

    def as_dict(self) -> Dict[str, Any]:
        """JSON-safe summary (telemetry / artifact codec)."""
        return {
            "config": self.config.to_dict(),
            "policy_signature": self.config.policy_signature(),
            "jobs": [
                {"name": r.job.name,
                 "speedup": r.result.speedup,
                 "original_time": r.result.original_time,
                 "optimized_time": r.result.optimized_time,
                 "cache_hit": r.cache_hit,
                 "transfer": r.transfer,
                 "seed_steps": r.seed_steps,
                 "clamped": r.result.clamped,
                 "stages": [dataclasses.asdict(s)
                            for s in r.result.stage_records]}
                for r in self.results
            ],
            "stats": self.stats.as_dict(),
            "verify_stats": (self.verify.as_dict()
                             if self.verify is not None else {}),
            "geomean_speedup": self.geomean_speedup,
        }

    def summary(self) -> str:
        s = self.stats
        base = (f"{len(self.results)} jobs: geomean {self.geomean_speedup:.2f}x, "
                f"{self.cache_hits} cache hits, {self.transfers} transfers "
                f"(engine: {s.cache_misses} misses, "
                f"{s.replay_fallbacks} replay fallbacks, "
                f"{s.transfer_fallbacks} transfer fallbacks)")
        v = self.verify
        if v is None:
            return base
        return base + (
            f"\nverify: {v.group_hits} group hits / {v.group_misses} misses, "
            f"{v.oracle_hits} oracle hits / {v.oracle_misses} misses, "
            f"{v.shared_group_hits} shared group hits, "
            f"{v.shared_oracle_hits} shared oracle hits, "
            f"{v.screened} screened; "
            f"planner: {v.planner_signatures} slices pre-executed, "
            f"{v.planner_deduped_jobs} jobs deduped")


class Forge:
    """Unified facade over pipeline + fleet engine: ``Forge(config)`` then
    ``optimize(job)`` / ``optimize_batch(jobs)``.

    ``config`` carries every knob (:class:`ForgeConfig`); live resources —
    knowledge base, LLM client, shared history, a pre-built result store —
    are keyword-only constructor arguments because they are stateful objects,
    not policy values (the KB's content hash and the LLM's presence still
    reach the cache key)."""

    def __init__(self, config: Optional[ForgeConfig] = None, *,
                 kb: Optional[KnowledgeBase] = None,
                 llm: Optional[LLMClient] = None,
                 history: Optional[History] = None,
                 cache: Optional[ResultStore] = None,
                 observers: Iterable = ()):
        self.config = config or ForgeConfig()
        if llm is not None and not self.config.use_llm:
            self.config = self.config.replace(use_llm=True)
        self.pipeline = ForgePipeline.from_config(self.config, kb=kb,
                                                  llm=llm, history=history)
        # registered observers fan out through one engine-held observer;
        # the engine serializes all dispatch (stage events arrive straight
        # from worker threads; job events via the notify path) so
        # observers never need to be thread-safe
        self._observers: List[Any] = []
        self._fan = FanOutObserver()
        self.engine = OptimizationEngine(pipeline=self.pipeline,
                                         workers=self.config.workers,
                                         cache=cache,
                                         cache_path=self.config.cache_path,
                                         cache_max_entries=self.config.cache_max_entries,
                                         backend=self.config.execution_backend,
                                         observer=self._fan)
        for obs in observers:
            self.add_observer(obs)

    # -- observers -------------------------------------------------------
    def add_observer(self, observer) -> "Forge":
        """Register an observer: a :class:`ForgeObserver`, or any legacy
        object exposing ``on_stage_complete`` / ``on_job_complete`` /
        ``on_transfer`` (adapted via :func:`as_observer`, same events,
        same order)."""
        self._observers.append(observer)
        self._fan.add(as_observer(observer))
        return self

    # -- optimization ----------------------------------------------------
    def optimize(self, job: KernelJob, on_stage=None,
                 observer=None) -> OptimizationReport:
        """Optimize one job (cache/transfer-aware)."""
        return self.optimize_batch([job], on_stage=on_stage,
                                   observer=observer)

    def optimize_batch(self, jobs: Sequence[KernelJob],
                       on_stage=None, observer=None) -> OptimizationReport:
        """Optimize a batch through the fleet engine; results come back in
        submission order inside a typed report. The report's stats are the
        *delta* this batch produced (a reused Forge accumulates lifetime
        counters on ``forge.stats``), so per-batch hit counts and engine
        counters always describe the same jobs.

        ``observer`` is an optional batch-scoped observer (new-protocol or
        legacy; see :func:`as_observer`) dispatched alongside the
        registered ones for this call only — its ``StageEvent.index`` is
        the job's submission index. ``on_stage(index, job_name, record)``
        is the deprecated loose-callback equivalent; unlike observers it
        is NOT serialized under the dispatch lock — the caller owns
        locking (unchanged historical contract)."""
        before = dataclasses.replace(self.engine.stats)
        vbefore = dataclasses.replace(self.engine.verify_stats)
        results = self.engine.run_batch(list(jobs), on_stage=on_stage,
                                        observer=observer)
        delta = EngineStats(**{
            f.name: getattr(self.engine.stats, f.name) - getattr(before, f.name)
            for f in dataclasses.fields(EngineStats)})
        vdelta = VerifyStats(**{
            f.name: (getattr(self.engine.verify_stats, f.name)
                     - getattr(vbefore, f.name))
            for f in dataclasses.fields(VerifyStats)})
        return OptimizationReport(
            results=results, stats=delta, config=self.config,
            verify=(vdelta if self.config.verify_fastpath != "off"
                    else None))

    def optimize_program(self, name: str, ci_program: KernelProgram,
                         bench_program: KernelProgram,
                         **job_kwargs) -> OptimizationReport:
        """Convenience: build the :class:`KernelJob` inline (tags, dtype,
        tolerances, meta forwarded)."""
        return self.optimize(KernelJob(name, ci_program, bench_program,
                                       **job_kwargs))

    # -- lifecycle -------------------------------------------------------
    def close(self):
        """Release executor resources (the process pool in particular —
        ``execution_backend='process'`` keeps spawned workers warm between
        batches). Idempotent; a closed Forge can still optimize — the next
        batch lazily rebuilds its executor."""
        self.engine.close()

    def __enter__(self) -> "Forge":
        return self

    def __exit__(self, *exc):
        self.close()

    # -- views -----------------------------------------------------------
    @property
    def stats(self) -> EngineStats:
        return self.engine.stats

    @property
    def verify_stats(self) -> VerifyStats:
        """Lifetime verify-layer counters (see ``engine.VerifyStats``)."""
        return self.engine.verify_stats

    @property
    def cache(self) -> ResultStore:
        return self.engine.cache

    @property
    def history(self) -> History:
        return self.pipeline.history
