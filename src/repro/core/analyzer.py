"""Rule-based analyzer (paper §IV-C stage 1).

Produces a typed issue inventory from a :class:`KernelProgram`. The paper's
analyzer is an LLM prompted with the kernel source + KB + problem context;
ours inspects the same information structurally. Severity scores (1-5) are
advisory. Re-invoked between stages (paper §IV-A-c) so the issue list tracks
the actual program state.
"""

from __future__ import annotations

from typing import List

from repro.core.context import ProblemContext
from repro.core.issues import Issue
from repro.hw.specs import dtype_itemsize
from repro.ir.graph import Graph
from repro.ir.rewrite import find_rewrites
from repro.ir.schedule import FusionGroup, KernelProgram

_REWRITE_ISSUE = {
    "matmul_reduce_to_vecmat": ("gemm_feeding_reduction", 5,
                                "GEMM output only consumed by a row/col sum — "
                                "the GEMM is algebraically eliminable",
                                "pre-reduce the operand (sum(xW, n) = x @ W.sum)"),
    "fold_scale_into_weights": ("foldable_scalar_epilogue", 3,
                                "scalar multiplier after GEMM/conv re-reads the "
                                "full output", "fold the scalar into the weights"),
    "fold_bn_into_conv": ("bn_after_conv", 3,
                          "inference batchnorm follows a conv",
                          "fold BN stats into conv weights/bias"),
    "cse": ("duplicated_subexpression", 3, "identical subexpressions computed "
            "twice", "compute once, reuse"),
    "mean_to_sum_scale": ("mean_uncanonicalized", 2, "mean hides a foldable sum",
                          "canonicalize to sum x (1/n)"),
    "tree_reduction": ("serial_accumulation", 3, "serial accumulator chain",
                       "use a tree reduction"),
    "transpose_elimination": ("materialized_transpose", 3,
                              "materialized transpose feeding a matmul",
                              "absorb into the matmul operand flag"),
}


def _vmem_working_set(graph: Graph, group: FusionGroup, compute_dtype: str) -> int:
    cfg = group.config
    if cfg is None:
        return 0
    isz = dtype_itemsize(compute_dtype)
    stream = (cfg.block_m * cfg.block_k + cfg.block_k * cfg.block_n) * isz
    acc = cfg.block_m * cfg.block_n * 4
    n_ops = sum(1 for n in group.nodes
                if len(graph.node(n).inputs) > 1) if group.nodes else 0
    epi = n_ops * cfg.block_m * cfg.block_n * isz
    return stream * max(1, cfg.num_stages) + acc + epi


def analyze(program: KernelProgram, ctx: ProblemContext) -> List[Issue]:
    g = program.graph
    sched = program.schedule
    issues: List[Issue] = []

    # ---- graph-level (algorithmic / discovery) -------------------------
    for rw in find_rewrites(g):
        if rw.rule in _REWRITE_ISSUE:
            typ, sev, desc, fix = _REWRITE_ISSUE[rw.rule]
            issues.append(Issue(typ, sev, f"{desc}: {rw.description}", fix,
                                rw.estimated_speedup,
                                proposal={"rule": rw.rule}))
        else:
            issues.append(Issue("open_ended", 4, rw.description,
                                rw.why_valid, rw.estimated_speedup,
                                proposal={"rule": rw.rule,
                                          "what": rw.description,
                                          "why_valid": rw.why_valid,
                                          "sketch": f"apply rewrite {rw.rule}",
                                          "estimated_speedup": rw.estimated_speedup}))

    for n in g.toposorted():
        if n.op in ("identity", "dropout"):
            issues.append(Issue("fusion_noop", 1,
                                f"{n.name} is a no-op in inference", "remove it",
                                node=n.name))
        if n.op == "sigmoid" and n.attrs.get("naive_exp"):
            issues.append(Issue("sigmoid_slow_exp", 2,
                                f"{n.name} computes sigmoid via 1/(1+exp(-x)) "
                                "with a division", "use the fused sigmoid",
                                node=n.name))
        if str(n.dtype) == "float64":
            issues.append(Issue("dtype_float64", 5,
                                f"{n.name} is float64 (no MXU support; XLA "
                                "emulates it)", "demote to float32",
                                "2-10x", node=n.name))
        if n.op == "input" and n.attrs.get("contiguous") is False:
            issues.append(Issue("non_contiguous_input", 2,
                                f"{n.name} arrives non-contiguous",
                                "normalize layout at the graph edge",
                                node=n.name))
        if n.op in ("conv2d", "conv3d", "conv_transpose2d", "conv_transpose3d"):
            layout = n.attrs.get("layout", "NCHW" if "2d" in n.op else "NCDHW")
            if layout.startswith("NC"):
                issues.append(Issue("suboptimal_conv_layout", 3,
                                    f"{n.name} uses {layout}; channels-last puts "
                                    "C on the 128-lane axis", "convert to NHWC",
                                    "1.1-1.7x", node=n.name))

    if ((program.meta.get("host_sync") or ctx.meta.get("host_sync"))
            and not program.meta.get("host_sync_removed")):
        issues.append(Issue("device_host_sync", 4,
                            "host-device synchronization in the hot path "
                            "(.item()-style stall between launches)",
                            "keep control flow on device", "varies"))

    # ---- dtype ----------------------------------------------------------
    if (sched.compute_dtype == "float32"
            and ctx.target_dtype in ("bfloat16", "bf16")):
        issues.append(Issue("dtype_precision", 4,
                            "compute dtype is f32; target allows bf16 inputs "
                            "with f32 accumulation (2x MXU rate, half traffic)",
                            "switch compute dtype to bfloat16", "2-4x"))
    casts = [n for n in g.toposorted() if n.op == "cast"]
    for n in casts:
        src = g.node(n.inputs[0])
        if src.op == "cast" or src.dtype == n.dtype:
            issues.append(Issue("dtype_input_conversion", 2,
                                f"redundant cast chain at {n.name}",
                                "cast once at the boundary", node=n.name))

    # ---- fusion ---------------------------------------------------------
    owner = {n: grp for grp in sched.groups for n in grp.nodes}
    for grp in sched.groups:
        last = g.node(grp.nodes[-1])
        consumers = g.consumers(last.name)
        if len(consumers) == 1 and last.name not in g.outputs:
            c = consumers[0]
            cg = owner.get(c.name)
            if cg is not None and cg is not grp:
                if last.is_contraction() or len(grp.nodes) > 0:
                    if c.is_elementwise():
                        typ = ("unfused_kernels" if last.is_contraction()
                               else "unfused_elementwise_chain")
                        issues.append(Issue(typ, 4,
                                            f"{c.name} launches separately from its "
                                            f"producer group {grp.name}",
                                            "fuse into one kernel", "2-3x",
                                            node=grp.name))
                    elif (c.op in ("reduce_sum", "reduce_max", "reduce_min",
                                   "reduce_mean")
                          and any(g.node(n).is_contraction() for n in grp.nodes)
                          and tuple(ax % 2 for ax in c.attrs.get("axes", ())) == (1,)):
                        issues.append(Issue("unfused_reduction_epilogue", 5,
                                            f"row reduction {c.name} materializes the "
                                            f"full GEMM output of {grp.name}",
                                            "accumulate the reduction in-tile",
                                            "2-10x", node=grp.name))
        ws = _vmem_working_set(g, grp, sched.compute_dtype)
        if ws > ctx.spec.vmem_bytes:
            issues.append(Issue("fusion_register_pressure", 4,
                                f"group {grp.name} working set {ws >> 20} MiB "
                                f"exceeds VMEM budget "
                                f"{ctx.spec.vmem_bytes >> 20} MiB",
                                "shrink blocks or split the fusion",
                                node=grp.name))
        if len(grp.nodes) > 8:
            issues.append(Issue("long_liveness", 2,
                                f"group {grp.name} keeps {len(grp.nodes)} "
                                "intermediates live", "reorder the chain",
                                node=grp.name))

    # ---- kernel-level (memory / block pointers / persistent / tpu) ------
    hw = ctx.hw
    for grp in sched.groups:
        root = g.node(grp.root)
        if grp.impl == "pallas_naive":
            issues.append(Issue("manual_pointer_arithmetic", 4,
                                f"group {grp.name} indexes tiles manually "
                                "(pl.load + pl.ds): Mosaic cannot pipeline",
                                "modernize to BlockSpec tiling", "1.3-2.5x",
                                node=grp.name))
            issues.append(Issue("missing_boundary_check", 3,
                                f"group {grp.name} has no ragged-edge masking",
                                "add bounds masks", node=grp.name))
        if root.op == "matmul" and root.attrs.get("transpose_b") \
                and grp.operand_layouts.get("b") != "packed":
            issues.append(Issue("uncoalesced_access", 4,
                                f"{grp.name}: B operand read column-strided "
                                "(transposed layout)", "repack to "
                                "lane-contiguous layout once", "1.5-2.8x",
                                node=grp.name))
            issues.append(Issue("missing_packed_transpose", 3,
                                f"{grp.name}: transpose re-done every call",
                                "cache the packed transpose", node=grp.name))
        cfg = grp.config
        if cfg is None:
            continue
        if grp.impl == "pallas_blockspec":
            if root.op == "matmul" and len(root.shape) == 2:
                m, n_ = root.shape
                a_shape = g.node(root.inputs[0]).shape
                k = a_shape[0] if root.attrs.get("transpose_a") else a_shape[-1]
                rec = hw.get_optimal_params(m, n_, k, sched.compute_dtype)
                if cfg.block_k < 128:
                    issues.append(Issue("suboptimal_tile_size", 4,
                                        f"{grp.name}: BLOCK_K={cfg.block_k} < 128 "
                                        "runs the MXU below native rate",
                                        f"use >=128 (query suggests {rec.block_k})",
                                        node=grp.name))
                elif (max(cfg.block_m, rec.block_m) >= 2 * min(cfg.block_m, rec.block_m)
                      or max(cfg.block_n, rec.block_n) >= 2 * min(cfg.block_n, rec.block_n)):
                    issues.append(Issue("suboptimal_tile_size", 3,
                                        f"{grp.name}: blocks ({cfg.block_m},"
                                        f"{cfg.block_n},{cfg.block_k}) far from "
                                        f"shape-aware recommendation "
                                        f"({rec.block_m},{rec.block_n},{rec.block_k})",
                                        "apply hw-query tiles", node=grp.name))
                mt = -(-m // cfg.block_m)
                nt = -(-n_ // cfg.block_n)
                if cfg.group_m <= 1 and mt > 1 and mt * nt >= 16 and rec.group_m > 1:
                    issues.append(Issue("no_swizzling", 3,
                                        f"{grp.name}: no GROUP_M traversal; A "
                                        f"re-streamed {nt}x from HBM",
                                        f"set group_m={rec.group_m}", "1.1-1.6x",
                                        node=grp.name))
                kt = -(-k // cfg.block_k)
                if kt > 1 and not cfg.persistent:
                    issues.append(Issue("missing_persistent", 4,
                                        f"{grp.name}: K split {kt}x without a "
                                        "persistent VMEM accumulator (partials "
                                        "spill to HBM)", "accumulate across the "
                                        "arbitrary K grid dim", "1.3-3x",
                                        node=grp.name))
            sub, lane = ctx.spec.min_tile(sched.compute_dtype)
            if cfg.block_m % sub or cfg.block_n % lane:
                issues.append(Issue("misaligned_block_shape", 4,
                                    f"{grp.name}: blocks ({cfg.block_m},"
                                    f"{cfg.block_n}) not ({sub},{lane})-aligned",
                                    "round to native tile multiples",
                                    node=grp.name))
            if cfg.num_stages < 2:
                issues.append(Issue("missing_pipeline_stages", 3,
                                    f"{grp.name}: num_stages={cfg.num_stages}; "
                                    "no copy/compute overlap",
                                    "double-buffer (stages>=2)", node=grp.name))
            if not cfg.dimension_semantics:
                issues.append(Issue("missing_dimension_semantics", 3,
                                    f"{grp.name}: no dimension_semantics; Mosaic "
                                    "serializes the grid", "mark parallel dims",
                                    node=grp.name))
            if cfg.acc_dtype != "float32":
                issues.append(Issue("bf16_accumulator", 5,
                                    f"{grp.name}: accumulates in {cfg.acc_dtype}",
                                    "accumulate f32", node=grp.name))
    if ctx.meta.get("hardcoded_grid"):
        issues.append(Issue("persistent_num_progs_hardcoded", 3,
                            "grid size hardcoded for one shape",
                            "derive from pl.cdiv(problem, block)"))

    # ---- autotuning -----------------------------------------------------
    has_pallas = any(grp.impl.startswith("pallas") for grp in sched.groups)
    if has_pallas and not program.meta.get("autotuned"):
        issues.append(Issue("missing_autotune", 2,
                            "no autotune grid evaluated for the final kernel "
                            "structure", "sweep the curated config grid",
                            "1.05-1.4x"))

    order = {i.type: k for k, i in enumerate(issues)}
    issues.sort(key=lambda i: (-i.severity, order.get(i.type, 0)))
    return issues
