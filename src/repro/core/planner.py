"""Stage planner (paper §IV-A): order the stage subset that has issues,
subject to hard dependency constraints encoding decreasing semantic scope.

The paper's planner is an LLM constrained by the DAG, falling back to the
default sequence on failure. Ours: an optional LLM client proposes an order
(validated against the DAG; invalid -> fallback); offline, a severity-greedy
topological sort — for equal dependency rank, stages whose issues carry the
highest severity go first.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.issues import Issue, stages_with_issues
from repro.core.llm import LLMClient
from repro.core.stages import DEFAULT_REGISTRY, RegistryView

# live registry views: these used to be the hand-maintained source of truth
# here; they are now *derived* from the stage registry and always current,
# even through the ``repro.core`` re-exports
DEFAULT_ORDER = RegistryView(DEFAULT_REGISTRY.default_order)
HARD_DEPS = RegistryView(DEFAULT_REGISTRY.dep_pairs)


def _respects_deps(order: List[str]) -> bool:
    pos = {s: i for i, s in enumerate(order)}
    for a, b in DEFAULT_REGISTRY.dep_pairs():
        if a in pos and b in pos and pos[a] > pos[b]:
            return False
    return True


def plan(issues: List[Issue], llm: Optional[LLMClient] = None) -> List[str]:
    """Return the ordered subset of stages to execute (skip logic included:
    a stage with no associated issue is not scheduled)."""
    active = stages_with_issues(issues)
    if not active:
        return []

    default_order = DEFAULT_REGISTRY.default_order()
    deps = DEFAULT_REGISTRY.dep_pairs()

    if llm is not None:
        try:
            resp = llm.complete(
                "You order kernel-optimization stages subject to hard "
                "dependency constraints. Reply with a comma-separated list.",
                f"stages: {active}\ndeps(before->after): {deps}\n"
                f"issues: {[(i.type, i.severity) for i in issues]}")
            order = [s.strip() for s in resp.split(",") if s.strip() in active]
            if len(set(order)) == len(active) and _respects_deps(order):
                return order
        except Exception:  # noqa: BLE001 — LLM failure -> default sequence
            pass
        return [s for s in default_order if s in active]

    # offline heuristic: severity-greedy topological sort
    sev: Dict[str, int] = {}
    for i in issues:
        sev[i.stage] = max(sev.get(i.stage, 0), i.severity)
    remaining = set(active)
    order: List[str] = []
    while remaining:
        ready = [s for s in remaining
                 if not any(a in remaining for a, b in deps if b == s)]
        if not ready:  # should not happen (DAG), but never deadlock
            ready = [s for s in default_order if s in remaining]
        ready.sort(key=lambda s: (-sev.get(s, 0), default_order.index(s)))
        nxt = ready[0]
        order.append(nxt)
        remaining.remove(nxt)
    return order
