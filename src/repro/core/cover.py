"""CoVeR agent: Chain-of-Verification-and-Refinement (paper §IV-B, Alg. 1).

The agent owns a *trajectory* — a growing log of thoughts, tool invocations
and observations — and loops: propose candidate → run the single
``compile_and_verify`` tool → on the success sentinel, return; otherwise the
observation (a structured error) feeds the next proposal. After T iterations a
fallback extractor returns the best-effort candidate, which the pipeline
re-verifies independently; if that fails the stage returns the original
program unchanged (never-degrade).

Trajectory management reproduces the paper's truncation policy: when the
formatted trajectory exceeds the context budget, the four oldest entries
(thought, tool, args, observation) are dropped; if only one tool call remains
the agent raises instead of operating without diagnostic context.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

from repro.core.context import ProblemContext
from repro.core.proposers import BaseProposer, Candidate
from repro.core.verify import (MIN_SPEEDUP, VerifyReport, run_correctness,
                               verify_candidate)
from repro.core.verify_cache import VerifySession
from repro.ir.cost import CostModel
from repro.ir.schedule import KernelProgram
from repro.kb.loader import KnowledgeBase


class TrajectoryOverflow(RuntimeError):
    pass


class Trajectory:
    """Key-value log with context-budget truncation.

    The budget check tracks a running character count instead of re-joining
    the whole log on every add (the old ``len(self.format())`` in the
    truncation loop made ``add`` O(total chars) — quadratic over a long
    CoVeR run). ``_formatted_len`` stays exactly equal to
    ``len(self.format())`` and is O(1): per-entry sizes (sans index digits)
    and the index-digit total are both maintained incrementally. Entries
    are indexed by *position*, so dropping the oldest shifts every index
    down by one — which is the same digit total as if the highest index had
    been removed, hence the O(1) update in :meth:`truncate_oldest`."""

    def __init__(self, max_chars: int = 60_000):
        self.entries: List[Dict[str, str]] = []
        self.max_chars = max_chars
        self._entry_chars: List[int] = []   # per-entry chars, sans index digits
        self._chars_sum = 0                 # == sum(self._entry_chars)
        self._digits_sum = 0                # == 3 * sum(len(str(i)) for i in range(n))

    def _formatted_len(self) -> int:
        n = len(self.entries)
        if n == 0:
            return 0
        return self._chars_sum + self._digits_sum + 3 * n - 1   # newlines

    def add(self, thought: str, tool: str, args: str, observation: str):
        self._digits_sum += 3 * len(str(len(self.entries)))     # new top index
        self.entries.append({"thought": thought, "tool": tool, "args": args,
                             "observation": observation})
        # the three format() lines for this entry, minus the index digits
        chars = (len(f"[] thought: {thought}") + len(f"[] tool: {tool}({args})")
                 + len(f"[] observation: {observation}"))
        self._entry_chars.append(chars)
        self._chars_sum += chars
        while self._formatted_len() > self.max_chars:
            self.truncate_oldest()

    def truncate_oldest(self):
        if len(self.entries) <= 1:
            raise TrajectoryOverflow(
                "cannot truncate further: a single tool call exceeds the "
                "context budget")
        self.entries.pop(0)
        self._chars_sum -= self._entry_chars.pop(0)
        self._digits_sum -= 3 * len(str(len(self.entries)))     # old top index

    def format(self) -> str:
        lines = []
        for i, e in enumerate(self.entries):
            lines += [f"[{i}] thought: {e['thought']}",
                      f"[{i}] tool: {e['tool']}({e['args']})",
                      f"[{i}] observation: {e['observation']}"]
        return "\n".join(lines)


@dataclasses.dataclass
class StageResult:
    stage: str
    improved: bool
    ci_program: KernelProgram
    bench_program: KernelProgram
    report: Optional[VerifyReport]
    iterations: int
    trajectory: Trajectory
    accepted: Optional[Candidate] = None
    fallback_used: bool = False
    # pattern_ids of every candidate the agent popped (including ones whose
    # transform errored), in attempt order — the attempt denominator the
    # history's mined success-rate priors are computed from
    tried_pattern_ids: List[str] = dataclasses.field(default_factory=list)


class CoVeRAgent:
    def __init__(self, stage: str, proposer: BaseProposer, kb: KnowledgeBase,
                 max_iterations: int = 5,
                 dump_dir: Optional[pathlib.Path] = None,
                 use_pallas_exec: bool = True,
                 session: Optional[VerifySession] = None,
                 fastpath: str = "off"):
        self.stage = stage
        self.proposer = proposer
        self.kb = kb
        self.T = max_iterations
        self.dump_dir = dump_dir
        self.use_pallas_exec = use_pallas_exec
        # verification fast path: a per-job memo session + the mode knob
        # (``ForgeConfig.verify_fastpath``); "off" or session=None is the
        # uncached reference behavior
        self.session = session
        self.fastpath = fastpath

    # ------------------------------------------------------------------
    def run(self, ci_program: KernelProgram, bench_program: KernelProgram,
            issues, ctx: ProblemContext, incumbent_time: float,
            cost_model: Optional[CostModel] = None,
            start_offset: int = 0) -> StageResult:
        cost_model = cost_model or CostModel(ctx.spec)
        trajectory = Trajectory()
        # the stage-scoped KB knowledge is the static part of the "prompt"
        _ = self.kb.format_for_llm(self.stage, list(ctx.tags))

        cands = list(self.proposer.candidates(bench_program, issues,
                                              trajectory.entries))
        if start_offset:
            cands = cands[start_offset:] + cands[:start_offset]
        tried: List[Tuple[Candidate, KernelProgram, KernelProgram, VerifyReport]] = []
        tried_ids: List[str] = []

        i = 0
        while i < self.T:
            # regenerate adaptively once the proposer has error feedback
            if i > 0:
                fresh = list(self.proposer.candidates(bench_program, issues,
                                                      trajectory.entries))
                seen = {c.description for c, *_ in tried}
                cands = [c for c in fresh if c.description not in seen] or cands
            if not cands:
                break
            # cost-ranked early stop: when every residual candidate carries a
            # roofline estimate that cannot clear the acceptance bar
            # (verify's performance gate rejects exactly this predicate), the
            # remaining verify budget is provably wasted — end the stage.
            # Estimates are only present under cost-ranked ordering, so the
            # legacy path never takes this branch.
            if all(c.cost_estimate is not None
                   and c.cost_estimate[0] * MIN_SPEEDUP >= incumbent_time
                   for c in cands):
                break
            cand = cands.pop(0)
            tried_ids.append(cand.pattern_id)
            try:
                new_ci = cand.transform(ci_program)
                new_bench = cand.transform(bench_program)
            except Exception as e:  # noqa: BLE001 — transform bugs are observations
                trajectory.add(cand.thought, "compile_and_verify",
                               cand.description,
                               f"TRANSFORM ERROR: {type(e).__name__}: {e}")
                i += 1
                continue
            report = verify_candidate(new_ci, new_bench, incumbent_time, ctx,
                                      self.kb, cost_model,
                                      use_pallas=self.use_pallas_exec,
                                      session=self.session,
                                      fastpath=self.fastpath)
            trajectory.add(cand.thought, "compile_and_verify",
                           cand.description, report.observation)
            tried.append((cand, new_ci, new_bench, report))
            if report.ok:
                return StageResult(self.stage, True, new_ci, new_bench, report,
                                   i + 1, trajectory, accepted=cand,
                                   tried_pattern_ids=tried_ids)
            i += 1

        # ---- fallback: ChainOfThought extraction over the trajectory ------
        # The unscreened cascade only reaches level "performance" after
        # correctness passed, so "best correct candidate" is min-by-time over
        # the performance-level reports. Under cost-first screening some of
        # those reports deferred correctness; walking the same reports in
        # ascending modeled time (stable sort = min()'s first-minimal
        # tie-break) and lazily executing deferred correctness selects
        # exactly the candidate the unscreened path would have.
        perf = [(c, ci, b, r) for c, ci, b, r in tried
                if r.level == "performance"]
        perf.sort(key=lambda t: t[3].candidate_time or 1e9)
        for cand, new_ci, new_bench, r in perf:
            if r.correctness_deferred:
                if self.session is not None:
                    self.session.stats.deferred_runs += 1
                if run_correctness(new_ci, ctx,
                                   use_pallas=self.use_pallas_exec,
                                   session=self.session) is not None:
                    continue       # would have failed level 3 before level 4
            report = verify_candidate(new_ci, new_bench, incumbent_time, ctx,
                                      self.kb, cost_model,
                                      use_pallas=self.use_pallas_exec,
                                      session=self.session,
                                      fastpath=self.fastpath)
            if report.ok:  # e.g. modeled time noise — accept if it now passes
                return StageResult(self.stage, True, new_ci, new_bench, report,
                                   self.T, trajectory, accepted=cand,
                                   fallback_used=True,
                                   tried_pattern_ids=tried_ids)
            break
        self._dump_failure(ci_program, trajectory)
        return StageResult(self.stage, False, ci_program, bench_program, None,
                           min(i, self.T), trajectory,
                           fallback_used=bool(tried),
                           tried_pattern_ids=tried_ids)

    # ------------------------------------------------------------------
    def _dump_failure(self, program: KernelProgram, trajectory: Trajectory):
        if self.dump_dir is None:
            return
        self.dump_dir.mkdir(parents=True, exist_ok=True)
        fname = self.dump_dir / f"{program.name}.{self.stage}.{int(time.time())}.json"
        fname.write_text(json.dumps({
            "program": program.dumps(),
            "stage": self.stage,
            "trajectory": trajectory.entries,
        }, indent=2))
