"""Problem context shared across pipeline components."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.hw.query import HardwareQuery
from repro.hw.specs import TPUSpec, TPU_V5E


@dataclasses.dataclass
class ProblemContext:
    """Everything a stage may consult (but not mutate)."""

    name: str
    target_dtype: str = "bfloat16"
    rtol: float = 1e-2                   # the paper's tolerances
    atol: float = 1e-5
    spec: TPUSpec = TPU_V5E
    tags: tuple = ()                     # e.g. ("gemm", "reduction")
    # trusted harness data (owned by the runner, never by candidates):
    ci_inputs: Optional[Dict[str, jnp.ndarray]] = None
    ci_params: Optional[Dict[str, jnp.ndarray]] = None
    oracle_outputs: Optional[Dict[str, jnp.ndarray]] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def hw(self) -> HardwareQuery:
        return HardwareQuery(self.spec)
