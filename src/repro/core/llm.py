"""LLM client interface (paper Listing 1 config surface).

The paper drives every stage with GPT-5.4 through an OpenAI-compatible API.
This container is offline, so the default proposers are deterministic
(KB-pattern engines, see ``proposers.py``); this module keeps the drop-in
seam: configure ``LLM_MODEL`` / ``OPENAI_API_BASE`` / ``OPENAI_API_KEY`` and
pass an :class:`OpenAIClient` to the pipeline to restore LLM-driven
generation. :class:`MockLLM` scripts responses for tests (including
adversarial ones — see tests/test_harness_separation.py).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, List, Optional


@dataclasses.dataclass
class LLMConfig:
    model: str = os.environ.get("LLM_MODEL", "")
    api_base: str = os.environ.get("OPENAI_API_BASE", "")
    api_key: str = os.environ.get("OPENAI_API_KEY", "")
    temperature: float = float(os.environ.get("LLM_TEMPERATURE", "1.0"))
    max_tokens: int = int(os.environ.get("LLM_MAX_TOKENS", "50000"))

    @property
    def configured(self) -> bool:
        return bool(self.model and self.api_base)


class LLMClient:
    """Interface: complete(system, prompt) -> str."""

    def complete(self, system: str, prompt: str) -> str:  # pragma: no cover
        raise NotImplementedError


class OpenAIClient(LLMClient):
    """Minimal OpenAI-compatible chat client (stdlib only; used when the
    operator provides an endpoint — never in offline CI)."""

    def __init__(self, config: Optional[LLMConfig] = None):
        self.config = config or LLMConfig()
        if not self.config.configured:
            raise RuntimeError(
                "OpenAIClient requires LLM_MODEL and OPENAI_API_BASE; "
                "offline runs use the deterministic proposer bank instead.")

    def complete(self, system: str, prompt: str) -> str:  # pragma: no cover
        import urllib.request
        body = json.dumps({
            "model": self.config.model,
            "temperature": self.config.temperature,
            "max_tokens": self.config.max_tokens,
            "messages": [{"role": "system", "content": system},
                         {"role": "user", "content": prompt}],
        }).encode()
        req = urllib.request.Request(
            self.config.api_base.rstrip("/") + "/chat/completions",
            data=body,
            headers={"Content-Type": "application/json",
                     "Authorization": f"Bearer {self.config.api_key}"})
        with urllib.request.urlopen(req, timeout=120) as resp:
            out = json.loads(resp.read())
        return out["choices"][0]["message"]["content"]


class MockLLM(LLMClient):
    """Scripted responses for tests."""

    def __init__(self, responses: Optional[List[str]] = None,
                 fn: Optional[Callable[[str, str], str]] = None):
        self.responses = list(responses or [])
        self.fn = fn
        self.calls: List[dict] = []

    def complete(self, system: str, prompt: str) -> str:
        self.calls.append({"system": system, "prompt": prompt})
        if self.fn is not None:
            return self.fn(system, prompt)
        if self.responses:
            return self.responses.pop(0)
        raise RuntimeError("MockLLM exhausted")
