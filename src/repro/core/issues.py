"""Issue taxonomy (paper §IV-C Table 1) + the deterministic issue→stage
routing table with dynamic registration for custom types.

Severity scores (1-5) are advisory — they inform prioritization within a
stage but never gate stage execution (paper §IV-C-a).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

# ---------------------------------------------------------------------------
# routing table: issue type -> exactly one pipeline stage
# ---------------------------------------------------------------------------

ISSUE_TO_STAGE: Dict[str, str] = {
    # algorithmic
    "redundant_computation": "algorithmic",
    "gemm_feeding_reduction": "algorithmic",
    "foldable_scalar_epilogue": "algorithmic",
    "bn_after_conv": "algorithmic",
    "duplicated_subexpression": "algorithmic",
    "serial_accumulation": "algorithmic",
    "materialized_transpose": "algorithmic",
    "mean_uncanonicalized": "algorithmic",
    # discovery
    "open_ended": "discovery",
    # dtype
    "dtype_float64": "dtype_fix",
    "dtype_precision": "dtype_fix",
    "dtype_input_conversion": "dtype_fix",
    # fusion
    "unfused_kernels": "fusion",
    "unfused_elementwise_chain": "fusion",
    "unfused_reduction_epilogue": "fusion",
    "fusion_noop": "fusion",
    "fusion_register_pressure": "fusion",
    "fusion_replaces_vendor": "fusion",
    # memory access
    "uncoalesced_access": "memory_access",
    "missing_boundary_check": "memory_access",
    "device_host_sync": "memory_access",
    "non_contiguous_input": "memory_access",
    "long_liveness": "memory_access",
    "high_register_pressure": "memory_access",
    "suboptimal_conv_layout": "memory_access",
    # block pointers
    "manual_pointer_arithmetic": "block_pointers",
    "block_ptr_boundary_wrong": "block_pointers",
    "block_ptr_multiple_of_misuse": "block_pointers",
    # persistent kernel
    "missing_persistent": "persistent_kernel",
    "persistent_num_progs_hardcoded": "persistent_kernel",
    # gpu (tpu) specific
    "suboptimal_tile_size": "gpu_specific",
    "misaligned_block_shape": "gpu_specific",
    "no_swizzling": "gpu_specific",
    "missing_pipeline_stages": "gpu_specific",
    "missing_dimension_semantics": "gpu_specific",
    "repack_in_forward": "gpu_specific",
    "missing_packed_transpose": "gpu_specific",
    "serialized_n_tiles": "gpu_specific",
    "sigmoid_slow_exp": "gpu_specific",
    "bf16_accumulator": "gpu_specific",
    # autotuning
    "missing_autotune": "autotuning",
}


def register_issue_type(issue_type: str, stage: str):
    """Dynamic registration (paper: 'with dynamic registration for custom
    issue types'). New KB files can route new issues without code changes."""
    from repro.kb.loader import STAGES
    if stage not in STAGES:
        raise ValueError(f"unknown stage {stage!r}")
    ISSUE_TO_STAGE[issue_type] = stage


@dataclasses.dataclass
class Issue:
    type: str
    severity: int                       # 1-5, advisory
    description: str
    suggested_fix: str = ""
    estimated_speedup: str = ""
    node: Optional[str] = None          # graph node or group the issue is on
    proposal: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # discovery issues must carry a detailed proposal:
    #   {what, why_valid, sketch, estimated_speedup}

    @property
    def stage(self) -> str:
        return ISSUE_TO_STAGE[self.type]

    def __repr__(self):
        return f"Issue({self.type}@{self.node}, sev={self.severity})"


def stages_with_issues(issues: List[Issue]) -> List[str]:
    """The set of stages that have >=1 associated issue (skip logic input)."""
    seen = []
    for i in issues:
        s = i.stage
        if s not in seen:
            seen.append(s)
    return seen
