"""Issue taxonomy (paper §IV-C Table 1).

The issue→stage routing table lives in the stage registry
(:mod:`repro.core.stages`): each :class:`~repro.core.stages.StageSpec`
declares the issue types it owns, and ``ISSUE_TO_STAGE`` here is the
registry's *live* mapping — dynamic registrations are visible everywhere
immediately, and a third-party stage brings its issue bindings with it.

Severity scores (1-5) are advisory — they inform prioritization within a
stage but never gate stage execution (paper §IV-C-a).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.stages import DEFAULT_REGISTRY

# the registry's live routing dict: issue type -> exactly one pipeline stage
ISSUE_TO_STAGE: Dict[str, str] = DEFAULT_REGISTRY.issue_to_stage


def register_issue_type(issue_type: str, stage: str):
    """Dynamic registration (paper: 'with dynamic registration for custom
    issue types'). New KB files can route new issues without code changes."""
    # StageRegistryError subclasses ValueError, matching the old contract
    DEFAULT_REGISTRY.bind_issue(issue_type, stage)


@dataclasses.dataclass
class Issue:
    type: str
    severity: int                       # 1-5, advisory
    description: str
    suggested_fix: str = ""
    estimated_speedup: str = ""
    node: Optional[str] = None          # graph node or group the issue is on
    proposal: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # discovery issues must carry a detailed proposal:
    #   {what, why_valid, sketch, estimated_speedup}

    @property
    def stage(self) -> str:
        return ISSUE_TO_STAGE[self.type]

    def __repr__(self):
        return f"Issue({self.type}@{self.node}, sev={self.severity})"


def stages_with_issues(issues: List[Issue]) -> List[str]:
    """The set of stages that have >=1 associated issue (skip logic input)."""
    seen = []
    for i in issues:
        s = i.stage
        if s not in seen:
            seen.append(s)
    return seen
