"""Per-stage candidate proposers.

Each proposer is the deterministic stand-in for the paper's LLM at one stage:
it reads the detected issues + the stage-scoped knowledge base (exactly the
prompt content ``format_for_llm`` assembles) and yields
:class:`Candidate` program transformations in priority order. Proposers are
*adaptive*: they read the trajectory's latest observation and react to
structured errors (VMEM overflow -> shrink BLOCK_K, alignment -> round up),
reproducing the refine half of CoVeR.

Candidates are pure functions ``KernelProgram -> KernelProgram``; they are
applied by the agent to both the ci- and bench-shaped programs so correctness
(small shapes) and structure/performance (deployment shapes) stay in sync.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.context import ProblemContext
from repro.core.issues import Issue
from repro.ir.graph import Graph, retype_graph
from repro.ir.rewrite import find_rewrites
from repro.ir.schedule import (FusionGroup, KernelProgram, PallasConfig,
                               Schedule, eager_schedule)
from repro.kb.loader import KnowledgeBase


@dataclasses.dataclass
class Candidate:
    thought: str
    description: str
    transform: Callable[[KernelProgram], KernelProgram]
    pattern_id: str = ""
    # roofline (total_s, hbm_bytes) of the transformed program, filled by the
    # scheduler's cost-ranked ordering pass; None when unranked (transform
    # failed to apply, or cost ranking disabled). The scheduler's early stop
    # reads total_s to prove a residual candidate can't beat the incumbent.
    cost_estimate: Optional[Tuple[float, float]] = None


Trajectory = List[Dict[str, str]]   # entries: {thought, tool, args, observation}


def _last_observation(trajectory: Trajectory) -> str:
    for entry in reversed(trajectory):
        if entry.get("observation"):
            return entry["observation"]
    return ""


def _rebuild_schedule_like(program: KernelProgram, new_graph: Graph) -> Schedule:
    """After a graph rewrite, rebuild the schedule: nodes that survived keep
    their group impl/config where the whole group survived; new/changed nodes
    get eager singleton groups."""
    old = program.schedule
    new_names = set(new_graph.nodes)
    groups: List[FusionGroup] = []
    claimed = set()
    for g in old.groups:
        if all(n in new_names for n in g.nodes):
            groups.append(FusionGroup.from_dict(g.to_dict()))
            claimed.update(g.nodes)
    for n in new_graph.toposorted():
        if n.op in ("input", "param", "const") or n.name in claimed:
            continue
        groups.append(FusionGroup(f"g_{n.name}", [n.name], n.name, "xla", None))
    return Schedule(groups=groups, compute_dtype=old.compute_dtype)


def _with_graph(program: KernelProgram, new_graph: Graph) -> KernelProgram:
    p = program.copy()
    p.graph = new_graph
    p.schedule = _rebuild_schedule_like(program, new_graph)
    p.validate()
    return p


def _mutate_group(program: KernelProgram, group_name: str,
                  fn: Callable[[FusionGroup], None]) -> KernelProgram:
    p = program.copy()
    for g in p.schedule.groups:
        if g.name == group_name:
            fn(g)
            return p
    # group names can differ between ci/bench programs only if a transform
    # diverged — treat as no-op rather than corrupt the program
    return p


def _main_matmul_groups(program: KernelProgram) -> List[FusionGroup]:
    g = program.graph
    return [grp for grp in program.schedule.groups
            if g.node(grp.root).op == "matmul" and len(g.node(grp.root).shape) == 2]


def _matmul_dims(program: KernelProgram, grp: FusionGroup):
    g = program.graph
    root = g.node(grp.root)
    m, n = root.shape[-2], root.shape[-1]
    a_shape = g.node(root.inputs[0]).shape
    k = a_shape[-2] if root.attrs.get("transpose_a") else a_shape[-1]
    return m, n, k


# ======================================================================
# stage proposers
# ======================================================================

class BaseProposer:
    stage = "base"

    def __init__(self, kb: KnowledgeBase, ctx: ProblemContext):
        self.kb = kb
        self.ctx = ctx

    def candidates(self, program: KernelProgram, issues: List[Issue],
                   trajectory: Trajectory) -> Iterator[Candidate]:
        raise NotImplementedError


class RewriteProposer(BaseProposer):
    """Algorithmic + discovery stages: apply KB-named graph rewrite rules."""

    def __init__(self, kb, ctx, stage: str):
        super().__init__(kb, ctx)
        self.stage = stage

    def candidates(self, program, issues, trajectory):
        rules: List[str] = []
        for i in issues:
            rule = i.proposal.get("rule")
            if rule and rule not in rules:
                rules.append(rule)
        # KB priority: patterns for this stage whose action names a rule
        kb_rules = [p.action.get("rule") for p in self.kb.patterns_for(self.stage)
                    if p.action.get("type") == "rewrite"]
        rules.sort(key=lambda r: kb_rules.index(r) if r in kb_rules else 99)

        if len(rules) > 1 or any(r in ("fold_scale_into_weights",
                                       "mean_to_sum_scale") for r in rules):
            # composite candidate first: the LLM rewrites holistically, so the
            # deterministic equivalent chains all applicable rules to fixpoint
            # (canonicalize -> fold -> eliminate compositions unlock the big
            # GEMM-elimination wins).
            all_rules = list(dict.fromkeys(rules + kb_rules))

            def fixpoint(p: KernelProgram, all_rules=tuple(all_rules)) -> KernelProgram:
                g = p.graph
                for _ in range(8):
                    cands = find_rewrites(g, rules=[r for r in all_rules if r])
                    if not cands:
                        break
                    g = cands[0].apply(g)
                if g is p.graph:
                    return p
                return _with_graph(p, g)

            yield Candidate(
                thought=f"[{self.stage}] chain all applicable algebraic rewrites "
                        f"to fixpoint ({', '.join(r for r in all_rules if r)}): "
                        "canonicalizations expose eliminations.",
                description="rewrite:fixpoint",
                transform=fixpoint,
                pattern_id="rewrite_fixpoint")

        for rule in rules:
            rewrites = find_rewrites(program.graph, rules=[rule])
            for rw in rewrites[:2]:
                why = rw.why_valid

                def transform(p: KernelProgram, rw_rule=rule) -> KernelProgram:
                    # re-find on the target program (ci/bench graphs differ in shape)
                    cands = find_rewrites(p.graph, rules=[rw_rule])
                    if not cands:
                        return p
                    return _with_graph(p, cands[0].apply(p.graph))

                yield Candidate(
                    thought=f"[{self.stage}] {rw.description}. Valid because: "
                            f"{why}. Expected {rw.estimated_speedup}.",
                    description=f"rewrite:{rule}",
                    transform=transform,
                    pattern_id=rule)


class DtypeProposer(BaseProposer):
    stage = "dtype_fix"

    def candidates(self, program, issues, trajectory):
        has_f64 = any(i.type == "dtype_float64" for i in issues)
        wants_bf16 = (any(i.type == "dtype_precision" for i in issues)
                      or has_f64) and self.ctx.target_dtype in ("bfloat16", "bf16")
        if has_f64 and wants_bf16:
            def to_bf16_direct(p: KernelProgram) -> KernelProgram:
                g2 = retype_graph(p.graph, lambda d: "float32" if d == "float64" else d)
                p2 = p.copy()
                p2.graph = g2
                p2.schedule = _rebuild_schedule_like(p, g2)
                p2.schedule.compute_dtype = "bfloat16"
                for grp in p2.schedule.groups:
                    if grp.config is not None:
                        grp.config.acc_dtype = "float32"
                return p2
            yield Candidate(
                thought="[dtype_fix] f64 storage on a bf16-native MXU: demote "
                        "straight to bf16 io with f32 accumulation (KB: "
                        "no_float64_on_tpu + mixed_precision_bf16).",
                description="dtype:f64->bf16+f32acc", transform=to_bf16_direct,
                pattern_id="mixed_precision_bf16")
        if has_f64:
            def demote(p: KernelProgram) -> KernelProgram:
                g2 = retype_graph(p.graph, lambda d: "float32" if d == "float64" else d)
                p2 = p.copy()
                p2.graph = g2
                p2.schedule = _rebuild_schedule_like(p, g2)
                return p2
            yield Candidate(
                thought="[dtype_fix] float64 has no MXU support; demote to f32 "
                        "and keep f32 accumulation (KB: no_float64_on_tpu).",
                description="dtype:f64->f32", transform=demote,
                pattern_id="demote_f64_to_f32")
        if any(i.type == "dtype_precision" for i in issues) \
                and self.ctx.target_dtype in ("bfloat16", "bf16"):
            def to_bf16(p: KernelProgram) -> KernelProgram:
                p2 = p.copy()
                p2.schedule.compute_dtype = "bfloat16"
                for grp in p2.schedule.groups:
                    if grp.config is not None:
                        grp.config.acc_dtype = "float32"
                return p2
            yield Candidate(
                thought="[dtype_fix] switch io/compute storage to bf16 with f32 "
                        "accumulators: 2x MXU rate, half the HBM traffic "
                        "(KB: mixed_precision_bf16).",
                description="dtype:f32->bf16+f32acc", transform=to_bf16,
                pattern_id="mixed_precision_bf16")
        if any(i.type == "dtype_input_conversion" for i in issues):
            def drop_casts(p: KernelProgram) -> KernelProgram:
                cands = find_rewrites(p.graph, rules=["eliminate_identities"])
                if not cands:
                    return p
                return _with_graph(p, cands[0].apply(p.graph))
            yield Candidate(
                thought="[dtype_fix] remove redundant cast chains (KB: "
                        "cast_at_boundaries_only).",
                description="dtype:drop-redundant-casts", transform=drop_casts,
                pattern_id="cast_at_boundaries_only")


class FusionProposer(BaseProposer):
    stage = "fusion"

    def _fuse_chain(self, p: KernelProgram, group_name: str,
                    include_reduction: bool) -> KernelProgram:
        """Greedily merge the single-consumer elementwise chain (and optional
        terminal row-reduction) following ``group_name`` into it."""
        p = p.copy()
        sched = p.schedule
        g = p.graph
        by_name = {grp.name: grp for grp in sched.groups}
        grp = by_name.get(group_name)
        if grp is None:
            # ci/bench name drift: locate by structure (first matmul group)
            mm = _main_matmul_groups(p)
            if not mm:
                return p
            grp = mm[0]
        owner = {n: gg for gg in sched.groups for n in gg.nodes}
        while True:
            last = g.node(grp.nodes[-1])
            cons = g.consumers(last.name)
            if len(cons) != 1 or last.name in g.outputs:
                break
            c = cons[0]
            cg = owner.get(c.name)
            if cg is None or cg is grp or len(cg.nodes) != 1:
                break
            is_red = (c.op in ("reduce_sum", "reduce_max", "reduce_min",
                               "reduce_mean")
                      and tuple(ax % 2 for ax in c.attrs.get("axes", ())) == (1,)
                      and not c.attrs.get("keepdims", False))
            if not (c.is_elementwise() or (include_reduction and is_red)):
                break
            grp.nodes.append(c.name)
            owner[c.name] = grp
            sched.groups.remove(cg)
            if is_red:
                break
        return p

    def candidates(self, program, issues, trajectory):
        fusion_issues = [i for i in issues
                         if i.type in ("unfused_kernels",
                                       "unfused_reduction_epilogue",
                                       "unfused_elementwise_chain")]
        red = [i for i in fusion_issues if i.type == "unfused_reduction_epilogue"]
        targets = []
        for i in red + fusion_issues:
            if i.node and i.node not in targets:
                targets.append(i.node)
        for t in targets:
            include_red = any(i.node == t and i.type == "unfused_reduction_epilogue"
                              for i in issues)
            red_note = (" and accumulate the row-reduction in-tile (the "
                        "[M,N] product never hits HBM)" if include_red else "")
            yield Candidate(
                thought=f"[fusion] merge the pointwise chain after {t} into one "
                        f"kernel{red_note} "
                        "(KB: fuse_epilogue_into_matmul"
                        + ("/fuse_reduction_epilogue" if include_red else "") + ").",
                description=f"fuse:{t}{'+reduction' if include_red else ''}",
                transform=lambda p, t=t, r=include_red: self._fuse_chain(p, t, r),
                pattern_id="fuse_reduction_epilogue" if include_red
                else "fuse_epilogue_into_matmul")
        if any(i.type == "fusion_noop" for i in issues):
            def drop_noops(p: KernelProgram) -> KernelProgram:
                cands = find_rewrites(p.graph, rules=["eliminate_identities"])
                if not cands:
                    return p
                return _with_graph(p, cands[0].apply(p.graph))
            yield Candidate(
                thought="[fusion] dead/no-op elimination (KB: eliminate_dead_fusion).",
                description="fuse:drop-noops", transform=drop_noops,
                pattern_id="eliminate_dead_fusion")
        if any(i.type == "fusion_register_pressure" for i in issues):
            def shrink(p: KernelProgram) -> KernelProgram:
                p = p.copy()
                for grp in p.schedule.groups:
                    if grp.config and grp.config.block_k > 128:
                        grp.config.block_k //= 2
                return p
            yield Candidate(
                thought="[fusion] working set exceeds VMEM: shrink BLOCK_K "
                        "(KB: fusion_vmem_pressure — K only changes pipeline "
                        "granularity).",
                description="fuse:shrink-blocks", transform=shrink,
                pattern_id="fusion_vmem_pressure")


class MemoryProposer(BaseProposer):
    stage = "memory_access"

    def candidates(self, program, issues, trajectory):
        for i in issues:
            if i.type in ("uncoalesced_access", "missing_packed_transpose") and i.node:
                yield Candidate(
                    thought=f"[memory_access] {i.description} — repack the B "
                            "operand once to lane-contiguous layout "
                            "(KB: pack_transposed_operand).",
                    description=f"mem:pack-b:{i.node}",
                    transform=lambda p, n=i.node: _mutate_group(
                        p, n, lambda grp: grp.operand_layouts.__setitem__("b", "packed")),
                    pattern_id="pack_transposed_operand")
                break
        for i in issues:
            if i.type == "missing_boundary_check" and i.node:
                yield Candidate(
                    thought=f"[memory_access] add ragged-edge masking on {i.node} "
                            "(KB: insert_bounds_masks).",
                    description=f"mem:mask:{i.node}",
                    transform=lambda p, n=i.node: _mutate_group(
                        p, n, lambda grp: setattr(grp.config or PallasConfig(),
                                                  "masked", True)),
                    pattern_id="insert_bounds_masks")
                break
        for i in issues:
            if i.type == "suboptimal_conv_layout" and i.node:
                def to_nhwc(p: KernelProgram, node=i.node) -> KernelProgram:
                    p = p.copy()
                    if node in p.graph.nodes:
                        p.graph.node(node).attrs["internal_layout"] = "NHWC"
                    else:
                        for n in p.graph.toposorted():
                            if n.op.startswith("conv"):
                                n.attrs["internal_layout"] = "NHWC"
                    return p
                yield Candidate(
                    thought=f"[memory_access] run {i.node} channels-last so C "
                            "lands on the 128-lane axis (KB: nhwc_for_conv).",
                    description=f"mem:nhwc:{i.node}", transform=to_nhwc,
                    pattern_id="nhwc_for_conv")
                break
        if any(i.type == "device_host_sync" for i in issues):
            def fix_sync(p: KernelProgram) -> KernelProgram:
                p = p.copy()
                p.meta["host_sync_removed"] = True
                return p
            yield Candidate(
                thought="[memory_access] hoist host-device sync out of the hot "
                        "path (KB: no_host_sync_in_hot_path).",
                description="mem:remove-host-sync", transform=fix_sync,
                pattern_id="no_host_sync_in_hot_path")
        memgrps = [i.node for i in issues if i.type == "long_liveness" and i.node]
        for n in memgrps[:1]:
            yield Candidate(
                thought=f"[memory_access] enable prefetch + early intermediate "
                        f"death in {n} (KB: prefetch_next_tile / "
                        "reduce_live_intermediates).",
                description=f"mem:prefetch:{n}",
                transform=lambda p, n=n: _mutate_group(
                    p, n, lambda grp: setattr(grp, "prefetch", True)),
                pattern_id="prefetch_next_tile")


class BlockPointerProposer(BaseProposer):
    stage = "block_pointers"

    def candidates(self, program, issues, trajectory):
        last_err = _last_observation(trajectory)
        shrink = "VMEM" in last_err
        targets = [i.node for i in issues
                   if i.type == "manual_pointer_arithmetic" and i.node]
        for attempt, div in enumerate((1, 2, 4)):
            def modernize(p: KernelProgram, div=div) -> KernelProgram:
                p = p.copy()
                hw = self.ctx.hw
                for grp in p.schedule.groups:
                    if grp.impl != "pallas_naive":
                        continue
                    grp.impl = "pallas_blockspec"
                    root = p.graph.node(grp.root)
                    if root.op == "matmul" and len(root.shape) == 2:
                        mm_grp = next(gg for gg in _main_matmul_groups(p)
                                      if gg.name == grp.name)
                        m, n, k = _matmul_dims(p, mm_grp)
                        rec = hw.get_optimal_params(m, n, k,
                                                    p.schedule.compute_dtype)
                        grp.config = PallasConfig(
                            block_m=max(8, rec.block_m // div),
                            block_n=max(128, rec.block_n // div),
                            block_k=max(128, rec.block_k // div),
                            group_m=1, num_stages=2, masked=True)
                    else:
                        grp.config = grp.config or PallasConfig(masked=True)
                return p
            if attempt > 0 and not shrink:
                break
            yield Candidate(
                thought="[block_pointers] modernize manual pl.load/pl.ds tile "
                        "indexing to BlockSpec index maps so Mosaic pipelines "
                        "HBM->VMEM copies (KB: tpu_block_modernization)"
                        + (f"; shrinking blocks /{div} after VMEM feedback"
                           if attempt else "") + ".",
                description=f"blockspec:modernize/{div}",
                transform=modernize,
                pattern_id="tpu_block_modernization")
            shrink = True  # allow further shrink attempts on repeated failures


class PersistentProposer(BaseProposer):
    stage = "persistent_kernel"

    def candidates(self, program, issues, trajectory):
        targets = [i.node for i in issues if i.type == "missing_persistent" and i.node]
        if targets:
            def persist(p: KernelProgram) -> KernelProgram:
                p = p.copy()
                for grp in p.schedule.groups:
                    if grp.impl == "pallas_blockspec" and grp.config:
                        grp.config.persistent = True
                        sem = list(grp.config.dimension_semantics or
                                   ("parallel", "arbitrary"))
                        if "arbitrary" not in sem:
                            sem[-1] = "arbitrary"
                        grp.config.dimension_semantics = tuple(sem)
                return p
            yield Candidate(
                thought="[persistent_kernel] keep the f32 accumulator in VMEM "
                        "scratch across the (arbitrary-marked) K grid dim; "
                        "partials stop round-tripping through HBM "
                        "(KB: persistent_accumulate).",
                description="persistent:acc", transform=persist,
                pattern_id="persistent_accumulate")


class GpuSpecificProposer(BaseProposer):
    stage = "gpu_specific"

    def candidates(self, program, issues, trajectory):
        hw = self.ctx.hw

        def apply_query(p: KernelProgram, shrink: int = 1) -> KernelProgram:
            p = p.copy()
            for grp in _main_matmul_groups(p):
                if not grp.impl.startswith("pallas"):
                    continue
                m, n, k = _matmul_dims(p, grp)
                rec = hw.get_optimal_params(m, n, k, p.schedule.compute_dtype)
                old = grp.config or PallasConfig()
                grp.config = PallasConfig(
                    block_m=max(8, rec.block_m // shrink),
                    block_n=max(128, rec.block_n // shrink),
                    block_k=max(128, rec.block_k // shrink),
                    group_m=rec.group_m,
                    num_stages=rec.num_stages,
                    dimension_semantics=("parallel", "arbitrary"),
                    acc_dtype="float32",
                    persistent=old.persistent,
                    masked=True)
            return p

        types = {i.type for i in issues}
        if types & {"suboptimal_tile_size", "misaligned_block_shape"}:
            last_err = _last_observation(trajectory)
            shrink = 2 if "VMEM" in last_err else 1
            yield Candidate(
                thought="[gpu_specific] replace imported NVIDIA-default tiles "
                        "with shape-aware MXU-aligned tiles from the hardware "
                        "query (KB: tpu_shape_aware_tiles).",
                description="tpu:query-tiles",
                transform=lambda p, s=shrink: apply_query(p, s),
                pattern_id="tpu_shape_aware_tiles")
            if shrink == 1:
                yield Candidate(
                    thought="[gpu_specific] VMEM feedback — halve streamed tiles.",
                    description="tpu:query-tiles/2",
                    transform=lambda p: apply_query(p, 2),
                    pattern_id="tpu_shape_aware_tiles")
        if "no_swizzling" in types:
            def swizzle(p: KernelProgram) -> KernelProgram:
                p = p.copy()
                for grp in _main_matmul_groups(p):
                    if grp.config:
                        m, n, k = _matmul_dims(p, grp)
                        rec = hw.get_optimal_params(m, n, k,
                                                    p.schedule.compute_dtype)
                        grp.config.group_m = max(rec.group_m, 2)
                return p
            yield Candidate(
                thought="[gpu_specific] GROUP_M grid traversal keeps the A block "
                        "VMEM-resident across n-steps (KB: tpu_grid_swizzling; "
                        "guard: >1 M-tile).",
                description="tpu:swizzle", transform=swizzle,
                pattern_id="tpu_grid_swizzling")
        if "missing_pipeline_stages" in types:
            yield Candidate(
                thought="[gpu_specific] double/triple-buffer HBM->VMEM copies "
                        "(KB: tpu_pipeline_depth).",
                description="tpu:stages",
                transform=lambda p: self._set_all(p, "num_stages", 2),
                pattern_id="tpu_pipeline_depth")
        if "bf16_accumulator" in types:
            yield Candidate(
                thought="[gpu_specific] pin accumulation to f32 "
                        "(KB: accumulate_f32).",
                description="tpu:f32acc",
                transform=lambda p: self._set_all(p, "acc_dtype", "float32"),
                pattern_id="accumulate_f32")
        if "missing_dimension_semantics" in types:
            yield Candidate(
                thought="[gpu_specific] mark parallel grid dims so Mosaic can "
                        "partition across TensorCores (KB: tpu_megacore_partition).",
                description="tpu:dimsem",
                transform=lambda p: self._set_all(
                    p, "dimension_semantics", ("parallel", "arbitrary")),
                pattern_id="tpu_megacore_partition")
        if "sigmoid_slow_exp" in types:
            def fix_sigmoid(p: KernelProgram) -> KernelProgram:
                p = p.copy()
                for n in p.graph.toposorted():
                    if n.op == "sigmoid":
                        n.attrs.pop("naive_exp", None)
                return p
            yield Candidate(
                thought="[gpu_specific] replace 1/(1+exp(-x)) with the fused "
                        "sigmoid primitive (no division).",
                description="tpu:sigmoid", transform=fix_sigmoid,
                pattern_id="sigmoid_slow_exp")

    @staticmethod
    def _set_all(p: KernelProgram, field: str, value) -> KernelProgram:
        p = p.copy()
        for grp in p.schedule.groups:
            if grp.config is not None:
                setattr(grp.config, field, value)
        return p


class AutotuneProposer(BaseProposer):
    stage = "autotuning"

    def candidates(self, program, issues, trajectory):
        from repro.ir.cost import CostModel
        cm = CostModel(self.ctx.spec)
        hw = self.ctx.hw
        groups = [grp for grp in _main_matmul_groups(program)
                  if grp.impl == "pallas_blockspec"]
        if not groups:
            return
        grp = groups[0]
        m, n, k = _matmul_dims(program, grp)
        grid = hw.autotune_grid(m, n, k, program.schedule.compute_dtype)

        scored = []
        for cfgp in grid:
            trial = program.copy()
            for g2 in trial.schedule.groups:
                if g2.name == grp.name and g2.config is not None:
                    g2.config.block_m = cfgp.block_m
                    g2.config.block_n = cfgp.block_n
                    g2.config.block_k = cfgp.block_k
                    g2.config.group_m = cfgp.group_m
                    g2.config.num_stages = cfgp.num_stages
            scored.append((cm.program_time(trial), cfgp))
        scored.sort(key=lambda t: t[0])

        def make_apply(c):
            def apply_cfg(p: KernelProgram) -> KernelProgram:
                p = p.copy()
                p.meta["autotuned"] = True
                for g2 in _main_matmul_groups(p):
                    if g2.impl == "pallas_blockspec" and g2.config is not None:
                        # clamp to this program's dims (ci programs are small)
                        mm, nn, kk = _matmul_dims(p, g2)
                        g2.config.block_m = max(8, min(c.block_m, mm))
                        g2.config.block_n = max(8, min(c.block_n, nn))
                        g2.config.block_k = max(8, min(c.block_k, kk))
                        g2.config.group_m = c.group_m
                        g2.config.num_stages = c.num_stages
                return p
            return apply_cfg

        for rank, (t_pred, cfgp) in enumerate(scored[:3]):
            yield Candidate(
                thought=f"[autotuning] curated-grid rank {rank}: "
                        f"({cfgp.block_m},{cfgp.block_n},{cfgp.block_k}) "
                        f"gm={cfgp.group_m} stages={cfgp.num_stages}, predicted "
                        f"{t_pred*1e6:.2f}us (KB: tpu_autotune_grid).",
                description=f"autotune:rank{rank}",
                transform=make_apply(cfgp),
                pattern_id="tpu_autotune_grid")


def make_proposer(stage: str, kb: KnowledgeBase, ctx: ProblemContext) -> BaseProposer:
    """Instantiate a stage's proposer via the stage registry — the factory is
    part of each :class:`~repro.core.stages.StageSpec`, so third-party stages
    plug in without touching this module."""
    from repro.core.stages import DEFAULT_REGISTRY
    return DEFAULT_REGISTRY.make_proposer(stage, kb, ctx)
