"""Fleet-scale optimization engine: concurrent multi-kernel scheduling over
a transfer-aware result store.

The paper runs Xe-Forge over 97 KernelBench-L2 kernels; at that scale the
single-kernel ``ForgePipeline.optimize`` loop wastes most of its work —
structurally identical programs (the GEMM family differs only in node labels)
re-run the full nine-stage CoVeR search from scratch, strictly sequentially.
The :class:`OptimizationEngine` fixes both axes:

* **Batching + pluggable execution backends** — jobs are scheduled across an
  executor selected by ``ForgeConfig.execution_backend``:

  - ``serial`` — in submission order on the calling thread (the
    deterministic reference mode);
  - ``thread`` — a bounded thread pool (the default; cheap, but the
    interpreter-heavy verify path contends on the GIL);
  - ``process`` — spawned worker processes, each owning a private pipeline
    built from the picklable :class:`ForgeConfig`; jobs travel as the
    :mod:`repro.core.job_codec` wire form and results/observer events stream
    back through a results queue.
  - ``remote`` — the same tagged worker protocol over TCP
    (:mod:`repro.core.fleet`): a ``FleetCoordinator`` dispatches to N
    ``forge-worker`` processes — loopback-spawned or connected from other
    hosts — with heartbeat loss detection and automatic re-dispatch of
    in-flight jobs.

  All four are **result-equivalent**: cache keys, transform logs, and
  optimized schedules are identical whichever backend ran a batch (results
  always come back in submission order, priors are frozen once per batch and
  transfer seeds once per phase). ``scripts/backend_equivalence.py`` gates
  this in CI.

* **Exact replay** — the :class:`ResultStore` (``repro.core.result_store``)
  keys entries on the canonical structural fingerprint of (graph, schedule,
  spec, tolerances) (:mod:`repro.ir.fingerprint`) *plus the KB content hash*
  — editing any KB YAML invalidates recorded sequences instead of replaying
  them forever. A hit replays the recorded :class:`TransformLog` — one
  verification per accepted transform instead of the full proposal search —
  and cross-checks that the replayed schedule is bit-identical to the cached
  canonical schedule. Any divergence falls back, so the cache can never
  produce a wrong result, only a slower path.

* **Family transfer** — on an exact miss, the rank-abstracted *family*
  fingerprint (same builder, different dims) is probed; a neighbor's log is
  handed to the stage scheduler as a speculative warm start
  (``StageScheduler.apply_seed``): each logged step is verified on the
  job's real shapes and the full proposal search resumes from wherever the
  transfer diverges. This is the paper's "the underlying optimization
  patterns remain largely consistent" premise made operational.

* **Warm starts** — the shared :class:`History` records every stage outcome;
  its success-count priors reorder proposer candidates for subsequent
  batches (see ``StageScheduler``). Process workers record to private
  histories whose records ride the results queue back and merge into the
  parent's history, so multi-batch warm starts stay backend-equivalent.
"""

from __future__ import annotations

import dataclasses
import hashlib
import multiprocessing
import pathlib
import pickle
import queue as queue_mod
import threading
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core import job_codec
from repro.core.config import EXECUTION_BACKENDS
from repro.core.observers import (JobEvent, StageEvent, TransferEvent,
                                  as_observer)
from repro.core.pipeline import ForgePipeline, PipelineResult, prepare_oracle
from repro.core.result_store import ResultCache, ResultStore
from repro.core.stage_scheduler import TransformLog
from repro.core.verify_cache import (SharedVerifyCache, VerifySession,
                                     run_program_cached)
from repro.ir.fingerprint import (fingerprint_family,
                                  fingerprint_family_ladder, fingerprint_job,
                                  job_dims_vector, program_canonical,
                                  program_exec_fingerprint)
from repro.ir.schedule import KernelProgram

__all__ = ["KernelJob", "EngineResult", "EngineStats", "VerifyStats",
           "OptimizationEngine", "ResultCache", "ResultStore", "execute_job",
           "replay_entry", "entry_for_result", "compute_job_keys",
           "fold_worker_result"]


@dataclasses.dataclass
class KernelJob:
    """One named optimization unit: the ci-shaped program the verifier
    executes and the bench-shaped program the cost model scores."""

    name: str
    ci_program: KernelProgram
    bench_program: KernelProgram
    tags: tuple = ()
    target_dtype: str = "bfloat16"
    rtol: float = 1e-2
    atol: float = 1e-5
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def fingerprint(self, spec_name: str, policy: str = "") -> str:
        return fingerprint_job(self.ci_program, self.bench_program,
                               spec_name, self.target_dtype,
                               self.rtol, self.atol, self.tags,
                               meta=self.meta, policy=policy)

    def family_fingerprint(self, spec_name: str, policy: str = "") -> str:
        """Rank-abstracted key: same builder at different dims collides."""
        return fingerprint_family(self.ci_program, self.bench_program,
                                  spec_name, self.target_dtype, self.tags,
                                  meta=self.meta, policy=policy)

    def family_ladder(self, spec_name: str, policy: str = "") -> tuple:
        """Graded ``((tier, key), ...)`` transfer keys, finest tier first;
        the last pair is exactly :meth:`family_fingerprint`."""
        return fingerprint_family_ladder(self.ci_program, self.bench_program,
                                         spec_name, self.target_dtype,
                                         self.tags, meta=self.meta,
                                         policy=policy)

    def dims_vector(self) -> tuple:
        """Concrete shape-extent vector for neighbor distance ranking."""
        return job_dims_vector(self.ci_program, self.bench_program)


@dataclasses.dataclass
class EngineResult:
    job: KernelJob
    result: PipelineResult
    fingerprint: str
    cache_hit: bool = False
    transfer: bool = False          # warm-started from a family neighbor
    seed_steps: int = 0             # neighbor steps that verified and stuck
    replay_fallback: bool = False   # exact hit whose replay diverged
    had_seed: bool = False          # a family seed was available for the run
    # the job's VerifySessionStats dict (None when the fast path is off):
    # lets a caller rebuild exactly the per-job stats delta the engine folded
    # into its lifetime counters (see OptimizationReport.from_result)
    verify: Optional[Dict[str, int]] = None


@dataclasses.dataclass
class EngineStats:
    jobs: int = 0
    cache_hits: int = 0             # exact fingerprint hit, replay succeeded
    cache_misses: int = 0           # full runs: no exact entry OR replay
                                    # diverged (those also count a fallback)
    replay_fallbacks: int = 0       # exact hit but replay diverged
    family_transfers: int = 0       # exact miss, neighbor seed (partially) applied
    transfer_fallbacks: int = 0     # neighbor found but no seed step applied

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class VerifyStats:
    """Engine-lifetime aggregate of the per-job ``VerifySessionStats`` plus
    the batch planner's counters (all flat ints, so facade-level batch
    deltas subtract field-wise like :class:`EngineStats`).

    Deliberately a separate object from :class:`EngineStats`: the backend-
    equivalence contract asserts EngineStats bit-identical across backends,
    but shared-cache hit counts legitimately differ — serial/thread sessions
    read one live engine-owned cache, while process workers see private
    per-worker caches warmed only by the planner's shipped slice. The
    *results* stay identical either way (a shared miss just re-executes);
    only the accounting of where an execution was saved moves."""

    group_hits: int = 0
    group_misses: int = 0
    trace_hits: int = 0
    trace_misses: int = 0
    structure_hits: int = 0
    structure_misses: int = 0
    cost_hits: int = 0
    cost_misses: int = 0
    oracle_hits: int = 0
    oracle_misses: int = 0
    screened: int = 0
    deferred_runs: int = 0
    shared_group_hits: int = 0      # group execs served by the shared layer
    shared_oracle_hits: int = 0     # oracle preps rebound from it
    planner_signatures: int = 0     # duplicated slices the planner executed
    planner_deduped_jobs: int = 0   # follower jobs that started warm
    planner_group_execs: int = 0    # group execs the planner paid up front
    planner_oracle_preps: int = 0   # oracle preps the planner paid up front

    def add_session(self, session_stats: Mapping[str, int]):
        for k, v in session_stats.items():
            if hasattr(self, k):
                setattr(self, k, getattr(self, k) + int(v))

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


# ----------------------------------------------------------------------
# Backend-independent single-job execution.
#
# These module-level functions are the one implementation of "optimize this
# job against this pipeline" that every backend shares: the serial loop and
# the thread pool call them against the engine's own pipeline; a process
# worker calls them against its private pipeline rebuilt from the shipped
# ForgeConfig. Keeping them free of engine state is what makes the three
# backends result-equivalent by construction.
# ----------------------------------------------------------------------

def compute_job_keys(pipeline: ForgePipeline, job: KernelJob) -> tuple:
    """(exact store key, family key, family ladder, dims vector) for a job
    against a pipeline. The exact key folds in the KB content hash so a KB
    edit turns every previously-exact hit into a miss; the transfer keys
    deliberately do not (transferred seeds are re-verified step-by-step, so
    stale ones are safe, just weaker). Transfer keys are also scoped by the
    *transfer* policy signature, which excludes search-order knobs — so
    stores written under the pre-knob signature stay transferable. The
    family key is the ladder's coarsest ("rank") tier.

    Module-level on purpose: the parent engine and spawned workers must
    derive bit-identical keys from the same inputs (the job codec's wire
    form round-trips fingerprints exactly), so the executors can push this
    — the serial chunk of dispatch — down into their workers."""
    spec = pipeline.spec.name
    policy = pipeline.policy_signature()
    fp = job.fingerprint(spec, policy)
    kb_hash = pipeline.kb.content_hash()
    exact = hashlib.sha256(f"{fp}|kb={kb_hash}".encode()).hexdigest()
    ladder = job.family_ladder(spec, pipeline.transfer_policy_signature())
    return exact, ladder[-1][1], ladder, job.dims_vector()


def entry_for_result(result: PipelineResult) -> Dict[str, Any]:
    """The result-store entry recording a cold run's winning sequence."""
    return {
        "name": result.name,
        "transform_log": (result.transform_log.to_list()
                          if result.transform_log else []),
        "canonical_schedule": program_canonical(
            result.bench_program)["schedule"],
        "original_time": result.original_time,
        "optimized_time": result.optimized_time,
        # never-degrade fired on the cold run: replay must reproduce the
        # clamp instead of treating final_time > original as divergence
        "clamped": result.clamped,
    }


def replay_entry(pipeline: ForgePipeline, job: KernelJob,
                 entry: Dict[str, Any],
                 priors: Mapping[str, int],
                 session=None, on_stage=None) -> Optional[PipelineResult]:
    """Replay a cached transform log onto this job's programs. Returns
    None (-> full optimization) on any divergence, including a replayed
    schedule that is not bit-identical to the cached canonical form.
    ``session`` is the job's verification memo: shared with the
    full-optimization fallback so a diverged replay's oracle prep and
    verified prefix are not paid for twice. ``on_stage`` is the per-job
    stage observer (replayed steps emit stage records too)."""
    log = TransformLog.from_list(entry.get("transform_log", []))
    ctx = pipeline._prepare_ctx(job.name, job.ci_program, job.tags,
                                job.target_dtype, job.rtol, job.atol,
                                job.meta or {}, session=session)
    original_cost = pipeline.cost_model.program_cost(job.bench_program)
    scheduler = pipeline.make_scheduler(
        priors, session=session,
        on_stage_complete=pipeline.stage_hook(on_stage))
    out = scheduler.replay(log, job.ci_program.copy(),
                           job.bench_program.copy(), ctx)
    if out is None:
        return None
    ci_prog, bench_prog, records = out
    got = program_canonical(bench_prog)["schedule"]
    if got != entry.get("canonical_schedule"):
        return None
    final_time = pipeline.cost_model.program_time(bench_prog)
    if final_time > original_cost.total_s:
        if not entry.get("clamped"):
            return None
        # reproduce the cold run's never-degrade clamp
        return PipelineResult(job.name, original_cost.total_s,
                              original_cost.total_s, ci_prog, bench_prog,
                              records, [], transform_log=log,
                              cache_hit=True, clamped=True)
    return PipelineResult(job.name, original_cost.total_s, final_time,
                          ci_prog, bench_prog, records, [],
                          transform_log=log, cache_hit=True)


def execute_job(pipeline: ForgePipeline, job: KernelJob,
                entry: Optional[Dict[str, Any]],
                seed_pairs: Sequence,
                exact_key: str,
                priors: Mapping[str, int],
                shared: Optional[SharedVerifyCache] = None,
                on_stage=None):
    """Replay-or-optimize one job. ``entry`` is the exact store entry (or
    None); ``seed_pairs`` is the frozen ``(neighbor_key, log_list)`` graded
    family-ladder snapshot for this job's phase (closest neighbor first); ``shared`` is the cross-job verification
    cache the job's session reads through / writes back (engine-owned on
    the in-process backends, per-worker on the process backend). Returns
    ``(PipelineResult, outcome)`` where ``outcome`` carries the store/stat
    flags::

        {"cache_hit", "replay_fallback", "had_seed", "transferred",
         "entry",    # entry: dict to store, or None on a replayed hit
         "verify"}   # the session's VerifySessionStats dict, or None
    """
    outcome = {"cache_hit": False, "replay_fallback": False,
               "had_seed": False, "transferred": False, "entry": None,
               "verify": None}
    # one verification memo for the job's whole lifecycle: replay attempt,
    # transfer seeding, and the full search all share it
    session = pipeline.make_verify_session(shared=shared)
    if entry is not None:
        replayed = replay_entry(pipeline, job, entry, priors,
                                session=session, on_stage=on_stage)
        if replayed is not None:
            outcome["cache_hit"] = True
            if session is not None:
                outcome["verify"] = session.stats.as_dict()
            return replayed, outcome
        outcome["replay_fallback"] = True

    # exact miss (or diverged replay): probe the phase's frozen family
    # snapshot for a transfer seed. The job's own exact entry is
    # excluded — when its replay just diverged, re-seeding from the very
    # log that failed cannot help — but another family member still can.
    seed_log: Optional[TransformLog] = None
    for neighbor_key, log_list in seed_pairs:
        if neighbor_key != exact_key and log_list:
            seed_log = TransformLog.from_list(log_list)
            break

    result = pipeline.optimize(
        job.name, job.ci_program, job.bench_program, tags=job.tags,
        target_dtype=job.target_dtype, rtol=job.rtol, atol=job.atol,
        meta=job.meta, priors=priors, seed_log=seed_log, session=session,
        on_stage=on_stage)
    outcome["entry"] = entry_for_result(result)
    outcome["had_seed"] = seed_log is not None
    outcome["transferred"] = (seed_log is not None
                              and result.seed_steps_applied > 0)
    if session is not None:
        outcome["verify"] = session.stats.as_dict()
    return result, outcome


def fold_worker_result(engine: "OptimizationEngine", job: KernelJob,
                       keys: tuple, payload: Mapping[str, Any],
                       notify=None) -> EngineResult:
    """Fold one worker's result payload (``{"result", "entry", "outcome",
    "history"}`` — the wire shape both process and remote workers return)
    into the parent engine: store the entry, apply the outcome to the
    stats, decode the result, and notify. The caller merges the history
    delta (in submission order, after its whole wave lands). Shared by
    the process and remote executors so the two transports cannot drift
    in how results are merged — the parent stays the single owner of
    store/stats/history on every backend."""
    exact_key, family_key = keys[0], keys[1]
    outcome = payload["outcome"]
    if payload["entry"] is not None:
        engine.cache.put(exact_key, payload["entry"], family=family_key,
                         flush=False, ladder=keys[2], dims=keys[3])
    engine._apply_outcome(outcome)
    result = job_codec.decode_pipeline_result(payload["result"])
    eres = EngineResult(job, result, exact_key,
                        cache_hit=outcome["cache_hit"],
                        transfer=outcome["transferred"],
                        seed_steps=result.seed_steps_applied,
                        replay_fallback=outcome["replay_fallback"],
                        had_seed=outcome["had_seed"],
                        verify=outcome.get("verify"))
    engine._notify_result(eres, notify)
    return eres


# ----------------------------------------------------------------------
# Execution backends. Each runs one scheduling *phase* (the engine's
# leader/follower split) and writes EngineResults into ``results`` at the
# jobs' submission indices.
# ----------------------------------------------------------------------

class SerialExecutor:
    """In-order execution on the calling thread — the reference backend."""

    name = "serial"

    def __init__(self, engine: "OptimizationEngine"):
        self.engine = engine

    def compute_keys(self, jobs) -> List[tuple]:
        return [compute_job_keys(self.engine.pipeline, job) for job in jobs]

    def run_phase(self, jobs, phase, keys, priors, seeds, results,
                  plan=None, on_stage=None, notify=None):
        # plan is unused in-process: jobs read the engine-owned shared
        # cache directly, which the planner already pre-populated
        for i in phase:
            results[i] = self.engine._run_job(jobs[i], keys[i], priors,
                                              seeds.get(i, ()),
                                              on_stage=_index_stage_hook(
                                                  on_stage, i),
                                              notify=notify)

    def end_batch(self):
        pass

    def close(self):
        pass


def _index_stage_hook(on_stage, index: int):
    """Bind a batch-level ``on_stage(index, job_name, record)`` callback to
    one job's submission index — the per-job hook execute_job expects."""
    if on_stage is None:
        return None

    def hook(job_name, record):
        on_stage(index, job_name, record)
    return hook


class ThreadExecutor:
    """Bounded thread pool (``workers`` threads); single-job phases and
    ``workers=1`` degrade to the serial path."""

    name = "thread"

    def __init__(self, engine: "OptimizationEngine"):
        self.engine = engine

    def compute_keys(self, jobs) -> List[tuple]:
        # deliberately serial: key computation is GIL-bound pure-Python
        # (toposort + canonical JSON), so a thread fan-out only pays pool
        # overhead — the worker-side win is real on the process backend,
        # where workers hash in parallel interpreters
        return [compute_job_keys(self.engine.pipeline, job) for job in jobs]

    def run_phase(self, jobs, phase, keys, priors, seeds, results,
                  plan=None, on_stage=None, notify=None):
        # plan unused here too — threads share the live engine-owned cache
        engine = self.engine
        if engine.workers <= 1 or len(phase) <= 1:
            for i in phase:
                results[i] = engine._run_job(jobs[i], keys[i], priors,
                                             seeds.get(i, ()),
                                             on_stage=_index_stage_hook(
                                                 on_stage, i),
                                             notify=notify)
            return
        with ThreadPoolExecutor(max_workers=engine.workers) as pool:
            futures = [(i, pool.submit(engine._run_job, jobs[i], keys[i],
                                       priors, seeds.get(i, ()),
                                       _index_stage_hook(on_stage, i),
                                       notify))
                       for i in phase]
            for i, f in futures:
                results[i] = f.result()

    def end_batch(self):
        pass

    def close(self):
        pass


def _process_worker_main(config_dict: Dict[str, Any],
                         kb_blob: Optional[bytes],
                         task_q, event_q):
    """Worker process loop: rebuild a private pipeline from the shipped
    ForgeConfig (+ pickled KB), then serve tasks until the ``None``
    sentinel. Tasks are tagged tuples: ``("keys", idx, job_wire)`` computes
    the job's exact/family cache keys worker-side (the wire codec makes the
    fingerprints bit-exact across the spawn boundary, so parent and worker
    derive identical keys); ``("job", idx, ...)`` optimizes. Observer events
    are not dropped: every stage record streams back through the results
    queue as it happens, and each finished job returns its wire-encoded
    result, store entry, outcome flags, and the private history delta for
    the parent to merge.

    The worker owns a private :class:`SharedVerifyCache` that persists
    across its tasks (cross-job sharing *within* the worker); each job task
    may additionally carry a parent-side warm slice — the planner-recorded
    shared-cache entries for the job's oracle slice, wire-encoded by
    :mod:`repro.core.job_codec` — which is installed before the job runs so
    planner dedup survives the process boundary."""
    from repro.core.config import ForgeConfig
    from repro.core.history import History

    config = ForgeConfig.from_dict(config_dict)
    kb = pickle.loads(kb_blob) if kb_blob else None
    pipeline = ForgePipeline.from_config(config, kb=kb)
    shared = None
    if (config.shared_verify_cache_bytes > 0
            and config.verify_fastpath != "off"):
        shared = SharedVerifyCache(config.shared_verify_cache_bytes)
    while True:
        task = task_q.get()
        if task is None:
            return
        kind, idx = task[0], task[1]
        try:
            if kind == "keys":
                job = job_codec.decode_job(task[2])
                event_q.put(("keys", idx, compute_job_keys(pipeline, job)))
                continue
            _, _, job_wire, exact_key, family_key, priors_wire, entry, \
                seed_pairs, warm_wire = task
            job = job_codec.decode_job(job_wire)
            priors = job_codec.decode_priors(priors_wire)
            if warm_wire is not None and shared is not None:
                for key, value in job_codec.decode_verify_slice(warm_wire):
                    shared.put(key, value)
            # fresh per-task history: the records travel back with the
            # result and merge into the parent's shared history, instead of
            # accumulating invisibly (and divergently) per worker
            pipeline.history = History()
            pipeline.on_stage_complete = (
                lambda name, rec, _idx=idx: event_q.put(
                    ("stage", _idx, name, job_codec.encode_stage_record(rec))))
            result, outcome = execute_job(pipeline, job, entry, seed_pairs,
                                          exact_key, priors, shared=shared)
            event_q.put(("result", idx, {
                "result": job_codec.encode_pipeline_result(result),
                "entry": outcome.pop("entry"),
                "outcome": outcome,
                "history": list(pipeline.history.records),
            }))
        except Exception:  # noqa: BLE001 — marshal the traceback up
            event_q.put(("error", idx, traceback.format_exc()))


class ProcessExecutor:
    """Spawned worker processes, each owning a private pipeline.

    The parent stays the single owner of the result store, the stats, the
    shared history, and observer dispatch: workers only ever see one job at
    a time plus the frozen seeds for it, and everything they produce —
    stage events, results, store entries, history records — flows back
    through one results queue. The ``spawn`` start method is used
    unconditionally (fork + JAX is a deadlock lottery), which is exactly why
    the :mod:`repro.core.job_codec` wire form exists."""

    name = "process"

    def __init__(self, engine: "OptimizationEngine"):
        if engine.pipeline.llm is not None:
            raise ValueError(
                "execution_backend='process' cannot ship a live LLM client "
                "to worker processes; use the 'thread' backend")
        self.engine = engine
        self._ctx = multiprocessing.get_context("spawn")
        self._task_q = None
        self._event_q = None
        self._procs: List = []
        # wire forms encoded once per batch by compute_keys and reused by
        # the job waves (keyed on the jobs-list identity so an interleaved
        # batch safely falls back to encoding); cleared by end_batch so a
        # finished batch neither pins its encodings nor can alias a future
        # jobs list that lands on a recycled id
        self._wires: Optional[tuple] = None     # (id(jobs), [wire, ...])
        # one phase at a time through the shared queues: two concurrent
        # run_batch calls must never drain each other's events (the serial/
        # thread paths tolerate overlap via the _inflight locks; here the
        # queues are the shared resource, so overlapping callers queue up)
        self._phase_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _ensure_pool(self):
        self._procs = [p for p in self._procs if p.is_alive()]
        if self._procs:
            return
        engine = self.engine
        self._task_q = self._ctx.Queue()
        self._event_q = self._ctx.Queue()
        config_dict = engine.pipeline.config.to_dict()
        kb_blob = pickle.dumps(engine.pipeline.kb)
        n = max(1, engine.workers)
        self._procs = [
            self._ctx.Process(target=_process_worker_main,
                              args=(config_dict, kb_blob, self._task_q,
                                    self._event_q),
                              daemon=True, name=f"forge-worker-{i}")
            for i in range(n)
        ]
        for p in self._procs:
            p.start()

    def _next_event(self):
        while True:
            try:
                return self._event_q.get(timeout=1.0)
            except queue_mod.Empty:
                if any(not p.is_alive() for p in self._procs):
                    try:  # drain anything the dying worker still flushed
                        return self._event_q.get_nowait()
                    except queue_mod.Empty:
                        raise RuntimeError(
                            "process backend worker died mid-batch "
                            "(see stderr for the worker traceback)")

    # ------------------------------------------------------------------
    def compute_keys(self, jobs) -> List[tuple]:
        """Fan the per-job fingerprint/key computation out to the worker
        pool — the ROADMAP's 'parent computes cache keys serially before
        dispatch' bottleneck. The phase lock keeps a concurrent run_batch
        from draining this wave's events."""
        with self._phase_lock:
            try:
                self._ensure_pool()
                wires = [job_codec.encode_job(job) for job in jobs]
                self._wires = (id(jobs), wires)
                keys: List[Optional[tuple]] = [None] * len(jobs)
                for i in range(len(jobs)):
                    self._task_q.put(("keys", i, wires[i]))
                pending = set(range(len(jobs)))
                while pending:
                    event = self._next_event()
                    if event[0] == "keys":
                        _, idx, pair = event
                        keys[idx] = tuple(pair)
                        pending.discard(idx)
                    else:  # "error" (stage/result events can't occur here)
                        _, idx, tb = event
                        raise RuntimeError(
                            f"process backend key computation for job "
                            f"#{idx} failed in worker:\n{tb}")
                return keys
            except Exception:
                self.close()
                raise

    # ------------------------------------------------------------------
    def run_phase(self, jobs, phase, keys, priors, seeds, results,
                  plan=None, on_stage=None, notify=None):
        with self._phase_lock:
            try:
                self._ensure_pool()
                # duplicate exact keys within a phase run as a second wave:
                # the first occurrence computes, the wave boundary puts its
                # entry in the store, and the duplicates replay — the same
                # 1-full-run + N-1-replays the _inflight locks give the
                # in-process backends, so cache_hit stays backend-equivalent
                seen = set()
                waves: List[List[int]] = [[], []]
                for i in phase:
                    waves[1 if keys[i][0] in seen else 0].append(i)
                    seen.add(keys[i][0])
                for wave in waves:
                    if wave:
                        self._run_wave(jobs, wave, keys, priors, seeds,
                                       results, plan, on_stage=on_stage,
                                       notify=notify)
            except Exception:
                # anything unexpected (a raising observer, a decode error, a
                # dead worker) leaves undispatched tasks / undrained events
                # behind; tear the pool down so the next batch starts clean
                # instead of consuming this batch's leftovers
                self.close()
                raise

    def _run_wave(self, jobs, wave, keys, priors, seeds, results, plan=None,
                  on_stage=None, notify=None):
        engine = self.engine
        wires = (self._wires[1] if self._wires
                 and self._wires[0] == id(jobs) else None)
        pending: Dict[int, KernelJob] = {}
        for i in wave:
            exact_key, family_key = keys[i][0], keys[i][1]
            wire = wires[i] if wires else job_codec.encode_job(jobs[i])
            # warm slice: the planner-recorded shared-cache entries for this
            # job's oracle slice, snapshotted parent-side at dispatch — the
            # worker's private cache cannot see the parent's, so the slice
            # rides the task (entries already evicted are simply skipped)
            warm_wire = None
            if plan and plan.get(i) and engine.verify_shared is not None:
                items = [(key, val) for key in plan[i]
                         if (val := engine.verify_shared.get(key)) is not None]
                if items:
                    warm_wire = job_codec.encode_verify_slice(items)
            self._task_q.put(("job", i, wire,
                              exact_key, family_key,
                              job_codec.encode_priors(priors),
                              engine.cache.get(exact_key),
                              list(seeds.get(i, ())), warm_wire))
            pending[i] = jobs[i]
        history_records: Dict[int, List[dict]] = {}
        while pending:
            event = self._next_event()
            kind = event[0]
            if kind == "stage":
                _, idx, job_name, record = event
                hook = engine.pipeline.on_stage_complete
                if hook is not None or on_stage is not None:
                    decoded = job_codec.decode_stage_record(record)
                    if hook is not None:
                        hook(job_name, decoded)
                    if on_stage is not None:
                        on_stage(idx, job_name, decoded)
            elif kind == "result":
                _, idx, payload = event
                eres = fold_worker_result(engine, pending.pop(idx),
                                          keys[idx], payload, notify=notify)
                history_records[idx] = payload["history"]
                results[idx] = eres
            else:  # "error"
                _, idx, tb = event
                raise RuntimeError(
                    f"process backend job #{idx} failed in worker:\n{tb}")
        # merge worker history deltas in submission order: counts are
        # additive (order-independent), the record list stays deterministic
        for i in sorted(history_records):
            engine.pipeline.history.merge_records(history_records[i])

    # ------------------------------------------------------------------
    def end_batch(self):
        self._wires = None

    def close(self):
        self._wires = None
        procs, self._procs = self._procs, []
        if not procs:
            return
        for p in procs:
            if p.is_alive() and self._task_q is not None:
                try:
                    self._task_q.put(None)
                except (ValueError, OSError):
                    break
        for p in procs:
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
                p.join(timeout=1)
        for q in (self._task_q, self._event_q):
            if q is not None:
                q.close()
                q.cancel_join_thread()
        self._task_q = self._event_q = None


class _RecordingSharedCache:
    """Planner-side wrapper over a :class:`SharedVerifyCache` that records
    every key its session touched (reads that hit + successful writes), in
    first-touch order — exactly the warm slice the process backend must
    ship to the planned jobs."""

    def __init__(self, inner: SharedVerifyCache):
        self._inner = inner
        self.keys: List[tuple] = []
        self._seen: set = set()

    def _note(self, key: tuple):
        if key not in self._seen:
            self._seen.add(key)
            self.keys.append(key)

    def get(self, key: tuple):
        got = self._inner.get(key)
        if got is not None:
            self._note(key)
        return got

    def put(self, key: tuple, value) -> bool:
        ok = self._inner.put(key, value)
        if ok:
            self._note(key)
        return ok


def _remote_executor(engine: "OptimizationEngine"):
    """Lazy factory for the distributed-fleet executor. The fleet module
    imports this one (for the worker protocol pieces), so registering the
    class directly would be an import cycle; a runtime import is also what
    keeps the socket stack out of every non-remote process."""
    from repro.core.fleet import RemoteExecutor
    return RemoteExecutor(engine)


_EXECUTORS = {
    "serial": SerialExecutor,
    "thread": ThreadExecutor,
    "process": ProcessExecutor,
    "remote": _remote_executor,
}

# single source of truth: ForgeConfig validates execution_backend against
# config.EXECUTION_BACKENDS, the engine dispatches through _EXECUTORS —
# fail at import if the two ever drift
assert set(_EXECUTORS) == set(EXECUTION_BACKENDS), \
    (sorted(_EXECUTORS), sorted(EXECUTION_BACKENDS))


class OptimizationEngine:
    """Suite-level orchestrator over a shared :class:`ForgePipeline`.

    New code should construct it through the :class:`repro.core.forge.Forge`
    facade (``Forge(ForgeConfig(...))``); the kwarg constructor remains as
    the compatibility shim, and ``config=`` supplies every operational knob
    (workers, execution backend, cache path/size) from one
    :class:`ForgeConfig`."""

    def __init__(self,
                 pipeline: Optional[ForgePipeline] = None,
                 workers: Optional[int] = None,
                 cache: Optional[ResultStore] = None,
                 cache_path: Optional[pathlib.Path] = None,
                 cache_max_entries: Optional[int] = None,
                 backend: Optional[str] = None,
                 config=None,
                 on_result=None,
                 observer=None):
        # explicit kwargs always win; config fills what was left unset
        if config is not None:
            pipeline = pipeline or ForgePipeline.from_config(config)
            workers = config.workers if workers is None else workers
            backend = backend or config.execution_backend
            cache_path = cache_path or config.cache_path
            if cache_max_entries is None:
                cache_max_entries = config.cache_max_entries
        self.pipeline = pipeline or ForgePipeline()
        self.workers = max(1, int(workers if workers is not None else 1))
        self.backend = backend or "thread"
        if self.backend not in EXECUTION_BACKENDS:
            raise ValueError(f"unknown execution backend {self.backend!r}; "
                             f"choose one of {sorted(EXECUTION_BACKENDS)}")
        self.cache = cache or ResultStore(
            cache_path,
            max_entries=(cache_max_entries if cache_max_entries is not None
                         else 512))
        self.stats = EngineStats()
        self.verify_stats = VerifyStats()
        # engine-owned cross-job verification cache: sessions of every job
        # this engine runs (serial/thread) read through and write back; the
        # process backend gives workers private caches plus planner warm
        # slices (see _process_worker_main). None when sharing is disabled.
        cfg = self.pipeline.config
        self.verify_shared: Optional[SharedVerifyCache] = (
            SharedVerifyCache(cfg.shared_verify_cache_bytes)
            if (cfg.shared_verify_cache_bytes > 0
                and cfg.verify_fastpath != "off") else None)
        # observer hook: called with each EngineResult as it completes
        # (serialized under a lock — observers need not be thread-safe)
        self.on_result = on_result
        # unified observer (core.observers.ForgeObserver or any legacy
        # object — as_observer adapts both): receives StageEvent/JobEvent/
        # TransferEvent for every batch this engine runs, serialized under
        # the notify lock
        self.observer = as_observer(observer)
        self._notify_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # per-key in-flight locks: duplicate jobs submitted in one batch
        # coalesce (first computes, the rest wait and replay) instead of
        # racing N full searches; pruned after every run_batch so the dict
        # doesn't grow without bound across a long-lived driver
        self._inflight: Dict[str, threading.Lock] = {}
        self._inflight_lock = threading.Lock()
        self._executors: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    def _get_executor(self, name: Optional[str] = None):
        name = name or self.backend
        if name not in self._executors:
            self._executors[name] = _EXECUTORS[name](self)
        return self._executors[name]

    def close(self):
        """Shut down live executors (the process pool in particular).
        Idempotent; the engine can be reused — the next batch lazily
        rebuilds whatever it needs."""
        executors, self._executors = self._executors, {}
        for ex in executors.values():
            ex.close()

    def __enter__(self) -> "OptimizationEngine":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _keys(self, job: KernelJob) -> tuple:
        """(exact key, family key, ladder, dims) — see
        :func:`compute_job_keys`. Kept as the single-job convenience; batch
        dispatch goes through the executor's ``compute_keys`` so the work
        can run worker-side."""
        return compute_job_keys(self.pipeline, job)

    # ------------------------------------------------------------------
    def submit(self, job: KernelJob) -> EngineResult:
        """Optimize one job (cache-aware). Single-job convenience over
        ``run_batch``."""
        return self.run_batch([job])[0]

    def run_batch(self, jobs: Sequence[KernelJob],
                  on_stage=None, observer=None) -> List[EngineResult]:
        """Optimize a batch. Results come back in submission order.

        ``observer`` is an optional per-batch :class:`~repro.core.observers
        .ForgeObserver` (or any legacy observer object — it is adapted via
        ``as_observer``): it receives every ``StageEvent`` (with the job's
        *submission index*), every ``JobEvent``, and ``TransferEvent``s for
        this batch, serialized under the engine's notify lock alongside the
        engine-level observer. This is the one observer surface new code
        should use.

        ``on_stage`` is the deprecated loose-callback form of the same
        thing, called as ``on_stage(index, job_name, record)`` with the
        job's submission index. It fires on every backend; on the process
        and remote backends the events are the ones streamed back from the
        workers. It is called unserialized (its original contract) — the
        caller owns any locking.

        Determinism: priors are frozen once per batch and transfer seeds
        once per *phase*, so a job's candidate ordering never depends on
        which other jobs happened to finish first — ``workers=1`` and
        ``workers=N`` (on any backend) are result-equivalent. Scheduling is
        two-phase: the first job of each family (the leader) runs in phase 1
        against the pre-batch store; remaining family members run in phase 2
        seeded from a snapshot taken at the phase boundary, so a cold leader
        can seed its in-batch siblings without making results racy."""
        cfg = self.pipeline.config
        priors = (self.pipeline.history.snapshot_priors(cfg.prior_policy)
                  if self.pipeline.warm_start else {})
        observers = [o for o in (self.observer, as_observer(observer))
                     if o is not None]
        stage_cb = self._stage_dispatcher(observers, on_stage)
        notify = self._result_dispatcher(observers)
        executor = self._get_executor()
        try:
            # key computation is dispatched through the executor so it runs
            # worker-side (threads / spawned processes) instead of
            # serializing on the parent before the first job can start
            keys = executor.compute_keys(jobs)
            # batch execution planning: execute each *duplicated* oracle
            # slice once, parent-side, pre-populating the shared cache so
            # every member of the duplicate set starts warm ("oracle-slice
            # leaders" — the family leader/follower idea at verify grain)
            plan = self._plan_batch(jobs)
            leaders: List[int] = []
            followers: List[int] = []
            seen = set()
            for i, k in enumerate(keys):
                # group by the coarsest (rank) tier: any finer-tier match
                # implies a rank match, so every potential in-batch seed
                # relationship crosses the phase boundary
                fam = k[1]
                (followers if fam in seen else leaders).append(i)
                seen.add(fam)
            results: List[Optional[EngineResult]] = [None] * len(jobs)
            for phase in (leaders, followers):
                if not phase:
                    continue
                # per-job graded neighbor snapshot, frozen at the phase
                # boundary (deterministic under any backend/worker count)
                seeds = {i: self.cache.ladder_members(keys[i][2], keys[i][3])
                         for i in phase}
                executor.run_phase(jobs, phase, keys, priors, seeds, results,
                                   plan=plan, on_stage=stage_cb,
                                   notify=notify)
            return results
        finally:
            executor.end_batch()
            self.cache.flush()
            # prune the coalescing locks: every job of this batch has
            # finished, so the entries are dead weight (a concurrent
            # run_batch re-creates any lock it still needs; worst case two
            # overlapping batches duplicate one search, never deadlock)
            with self._inflight_lock:
                self._inflight.clear()

    # ------------------------------------------------------------------
    def _plan_batch(self, jobs: Sequence[KernelJob]) -> Dict[int, list]:
        """Batch-level execution planner. Jobs are grouped by the rename-
        invariant :func:`program_exec_fingerprint` of their ci program; for
        each signature held by two or more jobs the first member's oracle
        prep + initial program execution run once, parent-side, through a
        session wired to the shared cache — every duplicate then replays
        those entries instead of re-executing them. Returns ``{job index:
        [shared-cache keys]}``, the warm slice the process backend ships at
        dispatch (in-process backends read the live cache and ignore it).

        Planning is a pure optimization: it only moves *where* the first
        execution of a slice happens, never its result, and any planner
        failure just leaves the affected jobs starting cold."""
        cfg = self.pipeline.config
        shared = self.verify_shared
        plan: Dict[int, list] = {}
        if shared is None or not cfg.batch_exec_planning:
            return plan
        sigs: Dict[str, List[int]] = {}
        for i, job in enumerate(jobs):
            try:
                sig = program_exec_fingerprint(job.ci_program)
            except Exception:  # noqa: BLE001 — planning must never raise
                continue
            sigs.setdefault(sig, []).append(i)
        for sig, idxs in sigs.items():
            if len(idxs) < 2:
                continue  # a unique slice warms nobody; the job runs it
            rep = jobs[idxs[0]]
            recorder = _RecordingSharedCache(shared)
            session = VerifySession(
                shared=recorder,
                check_shared=(cfg.verify_fastpath == "check"))
            try:
                inputs, params, _ = session.oracle_prep(
                    rep.ci_program.graph, prepare_oracle)
                run_program_cached(rep.ci_program, inputs, params, session,
                                   use_pallas=cfg.use_pallas_exec)
            except Exception:  # noqa: BLE001 — cold start, not a failure
                continue
            with self._stats_lock:
                vs = self.verify_stats
                vs.planner_signatures += 1
                vs.planner_deduped_jobs += len(idxs) - 1
                vs.planner_group_execs += (session.stats.group_misses
                                           - session.stats.shared_group_hits)
                vs.planner_oracle_preps += (
                    session.stats.oracle_misses
                    - session.stats.shared_oracle_hits)
            for i in idxs:
                plan[i] = list(recorder.keys)
        return plan

    # ------------------------------------------------------------------
    def _stage_dispatcher(self, observers, on_stage):
        """One internal ``(index, job_name, record)`` callback carrying both
        observer surfaces: typed observers see a :class:`StageEvent` under
        the notify lock; the deprecated loose ``on_stage`` callback fires
        outside it (its documented contract: the caller owns locking).
        ``None`` when nobody is listening, so backends can skip stage-event
        decode entirely."""
        if not observers and on_stage is None:
            return None

        def dispatch(index, job_name, record):
            if observers:
                event = StageEvent(job_name, record, index=index)
                with self._notify_lock:
                    for obs in observers:
                        obs.on_stage(event)
            if on_stage is not None:
                on_stage(index, job_name, record)
        return dispatch

    def _result_dispatcher(self, observers):
        """The per-batch job-completion dispatcher: legacy ``on_result``
        hook first, then every observer's ``on_job``, then (for transfer-
        seeded results) every observer's ``on_seed_transfer`` — the same
        ordering the old Forge fan-out produced. All under the notify lock
        so observers need not be thread-safe."""
        if not observers and self.on_result is None:
            return None

        def dispatch(eres: EngineResult):
            with self._notify_lock:
                if self.on_result is not None:
                    self.on_result(eres)
                event = JobEvent(eres)
                for obs in observers:
                    obs.on_job(event)
                if eres.transfer:
                    tevent = TransferEvent(eres)
                    for obs in observers:
                        obs.on_seed_transfer(tevent)
        return dispatch

    def _notify_result(self, eres: EngineResult, notify=None):
        """Deliver one completed result: through the batch dispatcher when
        one is active, else straight to the legacy ``on_result`` hook (the
        path for executors driven outside ``run_batch``)."""
        if notify is not None:
            notify(eres)
        elif self.on_result is not None:
            with self._notify_lock:
                self.on_result(eres)

    # ------------------------------------------------------------------
    def _apply_outcome(self, outcome: Mapping[str, Any]):
        """Fold one job's outcome flags into the engine stats (shared by the
        in-process paths and the process backend's parent-side accounting)."""
        with self._stats_lock:
            self.stats.jobs += 1
            if outcome["cache_hit"]:
                self.stats.cache_hits += 1
            else:
                self.stats.cache_misses += 1
                if outcome["had_seed"]:
                    if outcome["transferred"]:
                        self.stats.family_transfers += 1
                    else:
                        self.stats.transfer_fallbacks += 1
            if outcome["replay_fallback"]:
                self.stats.replay_fallbacks += 1
            verify = outcome.get("verify")
            if verify:
                self.verify_stats.add_session(verify)

    # ------------------------------------------------------------------
    def _run_job(self, job: KernelJob, keys: tuple,
                 priors: Mapping[str, int],
                 seed_pairs: Sequence, on_stage=None,
                 notify=None) -> EngineResult:
        exact_key = keys[0]
        with self._inflight_lock:
            job_lock = self._inflight.setdefault(exact_key, threading.Lock())
        with job_lock:
            eres = self._run_job_locked(job, keys, priors, seed_pairs,
                                        on_stage=on_stage)
        self._notify_result(eres, notify)
        return eres

    def _run_job_locked(self, job: KernelJob, keys: tuple,
                        priors: Mapping[str, int],
                        seed_pairs: Sequence, on_stage=None) -> EngineResult:
        exact_key, family_key = keys[0], keys[1]
        entry = self.cache.get(exact_key)
        result, outcome = execute_job(self.pipeline, job, entry,
                                      seed_pairs, exact_key, priors,
                                      shared=self.verify_shared,
                                      on_stage=on_stage)
        if outcome["entry"] is not None:
            self.cache.put(exact_key, outcome["entry"], family=family_key,
                           flush=False, ladder=keys[2], dims=keys[3])
        self._apply_outcome(outcome)
        return EngineResult(job, result, exact_key,
                            cache_hit=outcome["cache_hit"],
                            transfer=outcome["transferred"],
                            seed_steps=result.seed_steps_applied,
                            replay_fallback=outcome["replay_fallback"],
                            had_seed=outcome["had_seed"],
                            verify=outcome.get("verify"))
