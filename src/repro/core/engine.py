"""Fleet-scale optimization engine: concurrent multi-kernel scheduling over
a transfer-aware result store.

The paper runs Xe-Forge over 97 KernelBench-L2 kernels; at that scale the
single-kernel ``ForgePipeline.optimize`` loop wastes most of its work —
structurally identical programs (the GEMM family differs only in node labels)
re-run the full nine-stage CoVeR search from scratch, strictly sequentially.
The :class:`OptimizationEngine` fixes both axes:

* **Batching + concurrency** — jobs are scheduled across a bounded thread
  pool (verification is interpreter-bound, so threads suffice; ``workers=1``
  is the deterministic serial mode tests rely on). Results always come back
  in submission order, and history priors are frozen once per batch so
  serial and concurrent runs produce identical results kernel-for-kernel.

* **Exact replay** — the :class:`ResultStore` (``repro.core.result_store``)
  keys entries on the canonical structural fingerprint of (graph, schedule,
  spec, tolerances) (:mod:`repro.ir.fingerprint`) *plus the KB content hash*
  — editing any KB YAML invalidates recorded sequences instead of replaying
  them forever. A hit replays the recorded :class:`TransformLog` — one
  verification per accepted transform instead of the full proposal search —
  and cross-checks that the replayed schedule is bit-identical to the cached
  canonical schedule. Any divergence falls back, so the cache can never
  produce a wrong result, only a slower path.

* **Family transfer** — on an exact miss, the rank-abstracted *family*
  fingerprint (same builder, different dims) is probed; a neighbor's log is
  handed to the stage scheduler as a speculative warm start
  (``StageScheduler.apply_seed``): each logged step is verified on the
  job's real shapes and the full proposal search resumes from wherever the
  transfer diverges. This is the paper's "the underlying optimization
  patterns remain largely consistent" premise made operational.

* **Warm starts** — the shared :class:`History` records every stage outcome;
  its success-count priors reorder proposer candidates for subsequent
  batches (see ``StageScheduler``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import pathlib
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.pipeline import ForgePipeline, PipelineResult
from repro.core.result_store import ResultCache, ResultStore
from repro.core.stage_scheduler import TransformLog
from repro.ir.fingerprint import (fingerprint_family, fingerprint_job,
                                  program_canonical)
from repro.ir.schedule import KernelProgram

__all__ = ["KernelJob", "EngineResult", "EngineStats", "OptimizationEngine",
           "ResultCache", "ResultStore"]


@dataclasses.dataclass
class KernelJob:
    """One named optimization unit: the ci-shaped program the verifier
    executes and the bench-shaped program the cost model scores."""

    name: str
    ci_program: KernelProgram
    bench_program: KernelProgram
    tags: tuple = ()
    target_dtype: str = "bfloat16"
    rtol: float = 1e-2
    atol: float = 1e-5
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def fingerprint(self, spec_name: str, policy: str = "") -> str:
        return fingerprint_job(self.ci_program, self.bench_program,
                               spec_name, self.target_dtype,
                               self.rtol, self.atol, self.tags,
                               meta=self.meta, policy=policy)

    def family_fingerprint(self, spec_name: str, policy: str = "") -> str:
        """Rank-abstracted key: same builder at different dims collides."""
        return fingerprint_family(self.ci_program, self.bench_program,
                                  spec_name, self.target_dtype, self.tags,
                                  meta=self.meta, policy=policy)


@dataclasses.dataclass
class EngineResult:
    job: KernelJob
    result: PipelineResult
    fingerprint: str
    cache_hit: bool = False
    transfer: bool = False          # warm-started from a family neighbor
    seed_steps: int = 0             # neighbor steps that verified and stuck


@dataclasses.dataclass
class EngineStats:
    jobs: int = 0
    cache_hits: int = 0             # exact fingerprint hit, replay succeeded
    cache_misses: int = 0           # full runs: no exact entry OR replay
                                    # diverged (those also count a fallback)
    replay_fallbacks: int = 0       # exact hit but replay diverged
    family_transfers: int = 0       # exact miss, neighbor seed (partially) applied
    transfer_fallbacks: int = 0     # neighbor found but no seed step applied

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class OptimizationEngine:
    """Suite-level orchestrator over a shared :class:`ForgePipeline`.

    New code should construct it through the :class:`repro.core.forge.Forge`
    facade (``Forge(ForgeConfig(...))``); the kwarg constructor remains as
    the compatibility shim, and ``config=`` supplies every operational knob
    (workers, cache path/size) from one :class:`ForgeConfig`."""

    def __init__(self,
                 pipeline: Optional[ForgePipeline] = None,
                 workers: Optional[int] = None,
                 cache: Optional[ResultStore] = None,
                 cache_path: Optional[pathlib.Path] = None,
                 cache_max_entries: Optional[int] = None,
                 config=None,
                 on_result=None):
        # explicit kwargs always win; config fills what was left unset
        if config is not None:
            pipeline = pipeline or ForgePipeline.from_config(config)
            workers = config.workers if workers is None else workers
            cache_path = cache_path or config.cache_path
            if cache_max_entries is None:
                cache_max_entries = config.cache_max_entries
        self.pipeline = pipeline or ForgePipeline()
        self.workers = max(1, int(workers if workers is not None else 1))
        self.cache = cache or ResultStore(
            cache_path,
            max_entries=(cache_max_entries if cache_max_entries is not None
                         else 512))
        self.stats = EngineStats()
        # observer hook: called with each EngineResult as it completes
        # (serialized under a lock — observers need not be thread-safe)
        self.on_result = on_result
        self._notify_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # per-key in-flight locks: duplicate jobs submitted in one batch
        # coalesce (first computes, the rest wait and replay) instead of
        # racing N full searches; pruned after every run_batch so the dict
        # doesn't grow without bound across a long-lived driver
        self._inflight: Dict[str, threading.Lock] = {}
        self._inflight_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _keys(self, job: KernelJob) -> tuple:
        """(exact store key, family key). The exact key folds in the KB
        content hash so a KB edit turns every previously-exact hit into a
        miss; the family key deliberately does not (transferred seeds are
        re-verified step-by-step, so stale ones are safe, just weaker)."""
        spec = self.pipeline.spec.name
        policy = self.pipeline.policy_signature()
        fp = job.fingerprint(spec, policy)
        kb_hash = self.pipeline.kb.content_hash()
        exact = hashlib.sha256(f"{fp}|kb={kb_hash}".encode()).hexdigest()
        return exact, job.family_fingerprint(spec, policy)

    # ------------------------------------------------------------------
    def submit(self, job: KernelJob) -> EngineResult:
        """Optimize one job (cache-aware). Single-job convenience over
        ``run_batch``."""
        return self.run_batch([job])[0]

    def run_batch(self, jobs: Sequence[KernelJob]) -> List[EngineResult]:
        """Optimize a batch. Results come back in submission order.

        Determinism: priors are frozen once per batch and transfer seeds
        once per *phase*, so a job's candidate ordering never depends on
        which other jobs happened to finish first — ``workers=1`` and
        ``workers=N`` are result-equivalent. Scheduling is two-phase: the
        first job of each family (the leader) runs in phase 1 against the
        pre-batch store; remaining family members run in phase 2 seeded
        from a snapshot taken at the phase boundary, so a cold leader can
        seed its in-batch siblings without making results racy."""
        priors = (self.pipeline.history.snapshot_priors()
                  if self.pipeline.warm_start else {})
        try:
            keys = [self._keys(job) for job in jobs]
            leaders: List[int] = []
            followers: List[int] = []
            seen = set()
            for i, (_, fam) in enumerate(keys):
                (followers if fam in seen else leaders).append(i)
                seen.add(fam)
            results: List[Optional[EngineResult]] = [None] * len(jobs)
            for phase in (leaders, followers):
                if not phase:
                    continue
                seeds = {fam: self.cache.family_members(fam)
                         for fam in {keys[i][1] for i in phase}}
                if self.workers <= 1 or len(phase) <= 1:
                    for i in phase:
                        results[i] = self._run_job(jobs[i], keys[i],
                                                   priors, seeds)
                else:
                    with ThreadPoolExecutor(max_workers=self.workers) as pool:
                        futures = [(i, pool.submit(self._run_job, jobs[i],
                                                   keys[i], priors, seeds))
                                   for i in phase]
                        for i, f in futures:
                            results[i] = f.result()
            return results
        finally:
            self.cache.flush()
            # prune the coalescing locks: every job of this batch has
            # finished, so the entries are dead weight (a concurrent
            # run_batch re-creates any lock it still needs; worst case two
            # overlapping batches duplicate one search, never deadlock)
            with self._inflight_lock:
                self._inflight.clear()

    # ------------------------------------------------------------------
    def _run_job(self, job: KernelJob, keys: tuple,
                 priors: Mapping[str, int],
                 seeds: Mapping[str, list]) -> EngineResult:
        exact_key, family_key = keys
        with self._inflight_lock:
            job_lock = self._inflight.setdefault(exact_key, threading.Lock())
        with job_lock:
            eres = self._run_job_locked(job, exact_key, family_key, priors,
                                        seeds)
        if self.on_result is not None:
            with self._notify_lock:
                self.on_result(eres)
        return eres

    def _run_job_locked(self, job: KernelJob, exact_key: str,
                        family_key: str, priors: Mapping[str, int],
                        seeds: Mapping[str, list]) -> EngineResult:
        entry = self.cache.get(exact_key)
        if entry is not None:
            replayed = self._replay(job, entry, priors)
            if replayed is not None:
                with self._stats_lock:
                    self.stats.jobs += 1
                    self.stats.cache_hits += 1
                return EngineResult(job, replayed, exact_key, cache_hit=True)
            with self._stats_lock:
                self.stats.replay_fallbacks += 1

        # exact miss (or diverged replay): probe the phase's frozen family
        # snapshot for a transfer seed. The job's own exact entry is
        # excluded — when its replay just diverged, re-seeding from the very
        # log that failed cannot help — but another family member still can.
        seed_log: Optional[TransformLog] = None
        for neighbor_key, log_list in seeds.get(family_key, []):
            if neighbor_key != exact_key and log_list:
                seed_log = TransformLog.from_list(log_list)
                break

        result = self.pipeline.optimize(
            job.name, job.ci_program, job.bench_program, tags=job.tags,
            target_dtype=job.target_dtype, rtol=job.rtol, atol=job.atol,
            meta=job.meta, priors=priors, seed_log=seed_log)
        self.cache.put(exact_key, self._entry_for(result),
                       family=family_key, flush=False)
        transferred = seed_log is not None and result.seed_steps_applied > 0
        with self._stats_lock:
            self.stats.jobs += 1
            self.stats.cache_misses += 1
            if seed_log is not None:
                if transferred:
                    self.stats.family_transfers += 1
                else:
                    self.stats.transfer_fallbacks += 1
        return EngineResult(job, result, exact_key, cache_hit=False,
                            transfer=transferred,
                            seed_steps=result.seed_steps_applied)

    # ------------------------------------------------------------------
    def _entry_for(self, result: PipelineResult) -> Dict[str, Any]:
        return {
            "name": result.name,
            "transform_log": (result.transform_log.to_list()
                              if result.transform_log else []),
            "canonical_schedule": program_canonical(
                result.bench_program)["schedule"],
            "original_time": result.original_time,
            "optimized_time": result.optimized_time,
            # never-degrade fired on the cold run: replay must reproduce the
            # clamp instead of treating final_time > original as divergence
            "clamped": result.clamped,
        }

    def _replay(self, job: KernelJob, entry: Dict[str, Any],
                priors: Mapping[str, int]) -> Optional[PipelineResult]:
        """Replay a cached transform log onto this job's programs. Returns
        None (-> full optimization) on any divergence, including a replayed
        schedule that is not bit-identical to the cached canonical form."""
        log = TransformLog.from_list(entry.get("transform_log", []))
        pipeline = self.pipeline
        ctx = pipeline._prepare_ctx(job.name, job.ci_program, job.tags,
                                    job.target_dtype, job.rtol, job.atol,
                                    job.meta or {})
        original_cost = pipeline.cost_model.program_cost(job.bench_program)
        scheduler = pipeline.make_scheduler(priors)
        out = scheduler.replay(log, job.ci_program.copy(),
                               job.bench_program.copy(), ctx)
        if out is None:
            return None
        ci_prog, bench_prog, records = out
        got = program_canonical(bench_prog)["schedule"]
        if got != entry.get("canonical_schedule"):
            return None
        final_time = pipeline.cost_model.program_time(bench_prog)
        if final_time > original_cost.total_s:
            if not entry.get("clamped"):
                return None
            # reproduce the cold run's never-degrade clamp
            return PipelineResult(job.name, original_cost.total_s,
                                  original_cost.total_s, ci_prog, bench_prog,
                                  records, [], transform_log=log,
                                  cache_hit=True, clamped=True)
        result = PipelineResult(job.name, original_cost.total_s, final_time,
                                ci_prog, bench_prog, records, [],
                                transform_log=log, cache_hit=True)
        return result
