"""Fleet-scale optimization engine: concurrent multi-kernel scheduling with
fingerprint-keyed result caching.

The paper runs Xe-Forge over 97 KernelBench-L2 kernels; at that scale the
single-kernel ``ForgePipeline.optimize`` loop wastes most of its work —
structurally identical programs (the GEMM family differs only in node labels)
re-run the full nine-stage CoVeR search from scratch, strictly sequentially.
The :class:`OptimizationEngine` fixes both axes:

* **Batching + concurrency** — jobs are scheduled across a bounded thread
  pool (verification is interpreter-bound, so threads suffice; ``workers=1``
  is the deterministic serial mode tests rely on). Results always come back
  in submission order, and history priors are frozen once per batch so
  serial and concurrent runs produce identical results kernel-for-kernel.

* **Result caching** — a persistent :class:`ResultCache` keyed by the
  canonical structural fingerprint of (graph, schedule, spec, tolerances)
  (:mod:`repro.ir.fingerprint`). A hit replays the recorded
  :class:`TransformLog` — one verification per accepted transform instead of
  the full proposal search — and cross-checks that the replayed schedule is
  bit-identical to the cached canonical schedule. Any divergence falls back
  to full optimization, so the cache can never produce a wrong result, only
  a slower path.

* **Warm starts** — the shared :class:`History` records every stage outcome;
  its success-count priors reorder proposer candidates for subsequent
  batches (see ``StageScheduler``).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.pipeline import ForgePipeline, PipelineResult
from repro.core.stage_scheduler import TransformLog
from repro.ir.fingerprint import fingerprint_job, program_canonical
from repro.ir.schedule import KernelProgram


@dataclasses.dataclass
class KernelJob:
    """One named optimization unit: the ci-shaped program the verifier
    executes and the bench-shaped program the cost model scores."""

    name: str
    ci_program: KernelProgram
    bench_program: KernelProgram
    tags: tuple = ()
    target_dtype: str = "bfloat16"
    rtol: float = 1e-2
    atol: float = 1e-5
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def fingerprint(self, spec_name: str, policy: str = "") -> str:
        return fingerprint_job(self.ci_program, self.bench_program,
                               spec_name, self.target_dtype,
                               self.rtol, self.atol, self.tags,
                               meta=self.meta, policy=policy)


@dataclasses.dataclass
class EngineResult:
    job: KernelJob
    result: PipelineResult
    fingerprint: str
    cache_hit: bool = False


@dataclasses.dataclass
class EngineStats:
    jobs: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    replay_fallbacks: int = 0   # fingerprint hit but replay diverged

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class ResultCache:
    """Persistent fingerprint → winning-transform-sequence store.

    Entries hold the serialized :class:`TransformLog` plus the canonical form
    of the optimized bench schedule (the bit-identity witness) and the
    modeled timings. With a ``path`` the cache loads at construction and
    rewrites the JSON on every put — crash-safe enough for a driver loop and
    trivially inspectable. All access is lock-guarded for the worker pool.
    """

    def __init__(self, path: Optional[pathlib.Path] = None):
        self.path = pathlib.Path(path) if path else None
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._lock = threading.Lock()
        if self.path and self.path.exists():
            data = json.loads(self.path.read_text())
            self._entries = dict(data.get("entries", {}))

    def get(self, fingerprint: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            return self._entries.get(fingerprint)

    def put(self, fingerprint: str, entry: Dict[str, Any],
            flush: bool = True):
        """Insert an entry. ``flush=False`` defers the disk write (the
        engine batches inserts and flushes once per run_batch so concurrent
        workers don't serialize on whole-file rewrites)."""
        with self._lock:
            self._entries[fingerprint] = entry
            if flush:
                self._write_locked()

    def flush(self):
        with self._lock:
            self._write_locked()

    def _write_locked(self):
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self.path.write_text(json.dumps(
                {"entries": self._entries}, indent=2))

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self):
        with self._lock:
            self._entries.clear()
            if self.path and self.path.exists():
                self.path.unlink()


class OptimizationEngine:
    """Suite-level orchestrator over a shared :class:`ForgePipeline`."""

    def __init__(self,
                 pipeline: Optional[ForgePipeline] = None,
                 workers: int = 1,
                 cache: Optional[ResultCache] = None,
                 cache_path: Optional[pathlib.Path] = None):
        self.pipeline = pipeline or ForgePipeline()
        self.workers = max(1, int(workers))
        self.cache = cache or ResultCache(cache_path)
        self.stats = EngineStats()
        self._stats_lock = threading.Lock()
        # per-fingerprint in-flight locks: duplicate jobs submitted in one
        # batch coalesce (first computes, the rest wait and replay) instead
        # of racing N full searches
        self._inflight: Dict[str, threading.Lock] = {}
        self._inflight_lock = threading.Lock()

    # ------------------------------------------------------------------
    def submit(self, job: KernelJob) -> EngineResult:
        """Optimize one job (cache-aware). Single-job convenience over
        ``run_batch``."""
        return self.run_batch([job])[0]

    def run_batch(self, jobs: Sequence[KernelJob]) -> List[EngineResult]:
        """Optimize a batch. Results come back in submission order. Priors
        are frozen once per batch: a job's candidate ordering never depends
        on which other jobs happened to finish first, so ``workers=1`` and
        ``workers=N`` are result-equivalent."""
        priors = (self.pipeline.history.snapshot_priors()
                  if self.pipeline.warm_start else {})
        try:
            if self.workers <= 1 or len(jobs) <= 1:
                return [self._run_job(job, priors) for job in jobs]
            with ThreadPoolExecutor(max_workers=self.workers) as pool:
                futures = [pool.submit(self._run_job, job, priors)
                           for job in jobs]
                return [f.result() for f in futures]
        finally:
            self.cache.flush()

    # ------------------------------------------------------------------
    def _run_job(self, job: KernelJob,
                 priors: Mapping[str, int]) -> EngineResult:
        fp = job.fingerprint(self.pipeline.spec.name,
                             self.pipeline.policy_signature())
        with self._inflight_lock:
            job_lock = self._inflight.setdefault(fp, threading.Lock())
        with job_lock:
            return self._run_job_locked(job, fp, priors)

    def _run_job_locked(self, job: KernelJob, fp: str,
                        priors: Mapping[str, int]) -> EngineResult:
        entry = self.cache.get(fp)
        if entry is not None:
            replayed = self._replay(job, entry, priors)
            if replayed is not None:
                with self._stats_lock:
                    self.stats.jobs += 1
                    self.stats.cache_hits += 1
                return EngineResult(job, replayed, fp, cache_hit=True)
            with self._stats_lock:
                self.stats.replay_fallbacks += 1

        result = self.pipeline.optimize(
            job.name, job.ci_program, job.bench_program, tags=job.tags,
            target_dtype=job.target_dtype, rtol=job.rtol, atol=job.atol,
            meta=job.meta, priors=priors)
        self.cache.put(fp, self._entry_for(result), flush=False)
        with self._stats_lock:
            self.stats.jobs += 1
            self.stats.cache_misses += 1
        return EngineResult(job, result, fp, cache_hit=False)

    # ------------------------------------------------------------------
    def _entry_for(self, result: PipelineResult) -> Dict[str, Any]:
        return {
            "name": result.name,
            "transform_log": (result.transform_log.to_list()
                              if result.transform_log else []),
            "canonical_schedule": program_canonical(
                result.bench_program)["schedule"],
            "original_time": result.original_time,
            "optimized_time": result.optimized_time,
            # never-degrade fired on the cold run: replay must reproduce the
            # clamp instead of treating final_time > original as divergence
            "clamped": result.clamped,
        }

    def _replay(self, job: KernelJob, entry: Dict[str, Any],
                priors: Mapping[str, int]) -> Optional[PipelineResult]:
        """Replay a cached transform log onto this job's programs. Returns
        None (-> full optimization) on any divergence, including a replayed
        schedule that is not bit-identical to the cached canonical form."""
        log = TransformLog.from_list(entry.get("transform_log", []))
        pipeline = self.pipeline
        ctx = pipeline._prepare_ctx(job.name, job.ci_program, job.tags,
                                    job.target_dtype, job.rtol, job.atol,
                                    job.meta or {})
        original_cost = pipeline.cost_model.program_cost(job.bench_program)
        scheduler = pipeline.make_scheduler(priors)
        out = scheduler.replay(log, job.ci_program.copy(),
                               job.bench_program.copy(), ctx)
        if out is None:
            return None
        ci_prog, bench_prog, records = out
        got = program_canonical(bench_prog)["schedule"]
        if got != entry.get("canonical_schedule"):
            return None
        final_time = pipeline.cost_model.program_time(bench_prog)
        if final_time > original_cost.total_s:
            if not entry.get("clamped"):
                return None
            # reproduce the cold run's never-degrade clamp
            return PipelineResult(job.name, original_cost.total_s,
                                  original_cost.total_s, ci_prog, bench_prog,
                                  records, [], transform_log=log,
                                  cache_hit=True, clamped=True)
        result = PipelineResult(job.name, original_cost.total_s, final_time,
                                ci_prog, bench_prog, records, [],
                                transform_log=log, cache_hit=True)
        return result
