"""Transfer-aware result store: the two-level index behind the fleet engine.

PR 1's flat ``ResultCache`` was a pure memoizer — exact structural
fingerprint in, recorded :class:`TransformLog` out. This subsystem turns it
into the paper's "consistent optimization patterns" transfer mechanism with
two index levels:

* **Exact index** — fingerprint of (graph, schedule, spec, tolerances,
  policy) *plus the KB content hash* (folded in by the engine). A hit means
  the recorded winning sequence can be replayed verbatim and cross-checked
  for bit-identity. Because the KB hash participates, editing any KB YAML
  invalidates replay instead of pinning a stale sequence forever.

* **Family index** — rank-abstracted fingerprint
  (:func:`repro.ir.fingerprint.fingerprint_family`): same builder, different
  dims collide. On an exact miss with a family hit the engine *transfers*:
  the neighbor's log seeds the stage loop as a speculative warm start, each
  step re-verified on the real shapes. Family lookups are not KB-versioned —
  re-verification makes stale seeds safe, merely less effective.

Concurrency: entries are **sharded** — each shard owns its own lock and
dict, keys route by CRC32 — so concurrent exact-key lookups from engine
workers no longer serialize on one store-wide mutex (the parent's dispatch
hot path). LRU stays *globally* exact despite the sharding: every access
stamps a store-wide monotonic sequence number, eviction removes the
globally smallest stamp, and flush serializes shards merged in stamp order
— so the single-threaded behavior (and the on-disk layout) is bit-identical
to the unsharded store. The family index is small and keeps its own lock.

On-disk format (version 2)::

    {"version": 2,
     "entries": {"<exact_key>": {"family": "<family_key>",
                                 "transform_log": [...],
                                 "canonical_schedule": [...],
                                 "original_time": ..., "optimized_time": ...,
                                 "clamped": false, "name": "..."}}}

Entries are kept in LRU order (recency-stamp order; JSON round-trips it).
Loads are *tolerant*: corrupt JSON or an unknown ``version`` discards the
file and starts empty rather than crashing the driver. Writes are *atomic*:
serialized to a sibling tmp file, then ``os.replace``'d into place, so a
crash mid-flush can never leave a torn file. Eviction drops the
least-recently-used entry once ``max_entries`` is exceeded; the family index
is maintained alongside.
"""

from __future__ import annotations

import heapq
import json
import logging
import os
import pathlib
import threading
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.ir.fingerprint import dims_log_distance

log = logging.getLogger(__name__)

STORE_VERSION = 2

# Lock ordering (outer -> inner): evict lock > shard lock > family lock >
# seq lock. No path ever holds two shard locks at once — except clear(),
# which (under the evict lock) takes every shard lock in index order so a
# concurrent put can't leave the entry count and the shards disagreeing.


def _entry_index_keys(entry: Dict[str, Any]) -> List[str]:
    """Every transfer-index key an entry is reachable under: its (rank)
    family key plus any graded ladder keys. The ladder's "rank" tier is
    byte-identical to the family key, so pre-ladder entries (no
    ``family_ladder`` field) are simply reachable at the coarsest tier."""
    keys = dict.fromkeys([entry.get("family")] if entry.get("family") else [])
    ladder = entry.get("family_ladder")
    if isinstance(ladder, dict):
        for fam in ladder.values():
            if fam:
                keys.setdefault(fam)
    return list(keys)


class _Shard:
    __slots__ = ("lock", "entries")

    def __init__(self):
        self.lock = threading.Lock()
        # key -> [recency_seq, entry_dict]
        self.entries: Dict[str, list] = {}


class ResultStore:
    """Two-level (exact + family) LRU store of winning transform sequences.

    Entry access is shard-locked (see module docstring) for the engine's
    worker pool. ``get``/``put`` keep the PR-1 ``ResultCache`` surface (the
    engine and older tests use them), extended with the family index and
    eviction.
    """

    def __init__(self, path: Optional[pathlib.Path] = None,
                 max_entries: int = 512, shards: int = 8):
        self.path = pathlib.Path(path) if path else None
        self.max_entries = max(1, int(max_entries))
        self._shards = [_Shard() for _ in range(max(1, int(shards)))]
        self._family: Dict[str, List[str]] = {}   # family_key -> member keys
        self._family_lock = threading.Lock()
        self._seq_lock = threading.Lock()
        self._seq = 0
        self._count = 0                           # guarded by _seq_lock
        # lazy min-heap of (seq, key) recency stamps (guarded by _seq_lock):
        # every get/put pushes, eviction pops — stale stamps (the entry was
        # re-stamped or removed since) are skipped by comparing against the
        # entry's current seq, so eviction is O(log n) amortized instead of
        # a full scan per victim
        self._recency: List[tuple] = []
        self._evict_lock = threading.Lock()
        self._io_lock = threading.Lock()
        self.evictions = 0
        if self.path and self.path.exists():
            self._load()

    # ------------------------------------------------------------------
    def _shard(self, key: str) -> _Shard:
        return self._shards[zlib.crc32(key.encode()) % len(self._shards)]

    def _stamp(self, key: str) -> int:
        with self._seq_lock:
            self._seq += 1
            heapq.heappush(self._recency, (self._seq, key))
            return self._seq

    # ------------------------------------------------------------------
    def _load(self):
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            log.warning("result store %s is corrupt (%s); starting empty",
                        self.path, e)
            return
        if not isinstance(data, dict) or data.get("version") != STORE_VERSION:
            log.warning("result store %s has version %r (want %d); discarded",
                        self.path, data.get("version") if isinstance(data, dict)
                        else None, STORE_VERSION)
            return
        entries = data.get("entries", {})
        if not isinstance(entries, dict):
            return
        for key, entry in entries.items():
            if not isinstance(entry, dict):
                continue
            # file order is LRU->MRU; sequential stamps reproduce it
            self._shard(key).entries[key] = [self._stamp(key), entry]
            self._count += 1
            for fam in _entry_index_keys(entry):
                self._index_family(key, fam)
        # honor this instance's cap even against a larger on-disk file
        # (a replay-only run would otherwise never reach put's eviction)
        self._evict()

    def _index_family(self, key: str, family: Optional[str]):
        if family:
            with self._family_lock:
                keys = self._family.setdefault(family, [])
                if key not in keys:
                    keys.append(key)

    def _unindex_family(self, key: str, family: Optional[str]):
        if family:
            with self._family_lock:
                keys = self._family.get(family, [])
                if key in keys:
                    keys.remove(key)
                if not keys:
                    self._family.pop(family, None)

    # ------------------------------------------------------------------
    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Exact lookup. A hit refreshes the entry's LRU recency."""
        sh = self._shard(key)
        with sh.lock:
            rec = sh.entries.get(key)
            if rec is None:
                return None
            rec[0] = self._stamp(key)             # move to MRU
            return rec[1]

    def put(self, key: str, entry: Dict[str, Any],
            family: Optional[str] = None, flush: bool = True,
            ladder: Optional[Sequence[Tuple[str, str]]] = None,
            dims: Optional[Sequence[int]] = None):
        """Insert/refresh an entry. ``family`` threads the (rank) transfer
        index; ``ladder`` is the graded ``((tier, key), ...)`` sequence from
        :func:`repro.ir.fingerprint.fingerprint_family_ladder` and ``dims``
        the concrete shape vector — both optional (older callers and
        pre-ladder store files keep working, reachable at the rank tier).
        ``flush=False`` defers the disk write (the engine batches inserts
        and flushes once per ``run_batch``)."""
        if family or ladder or dims is not None:
            entry = dict(entry)
        if family:
            entry["family"] = family
        if ladder:
            entry["family_ladder"] = {tier: fam for tier, fam in ladder}
        if dims is not None:
            entry["dims"] = [int(d) for d in dims]
        sh = self._shard(key)
        with sh.lock:
            old = sh.entries.pop(key, None)
            sh.entries[key] = [self._stamp(key), entry]
            if old is None:
                # counted inside the shard lock so clear() (which holds
                # every shard lock) can never interleave between the insert
                # and the count update
                with self._seq_lock:
                    self._count += 1
        new_fams = set(_entry_index_keys(entry))
        if old is not None:
            # re-put under different (or no) transfer keys: drop the stale
            # index entries so get_family never serves a disowned key
            for old_fam in _entry_index_keys(old[1]):
                if old_fam not in new_fams:
                    self._unindex_family(key, old_fam)
        for fam in _entry_index_keys(entry):
            self._index_family(key, fam)
        self._evict()
        if flush:
            self.flush()

    # ------------------------------------------------------------------
    def _rebuild_recency(self):
        """Rebuild the stamp heap from live entries (rare: only when lazy
        deletion left it empty while over cap, e.g. after clear() raced)."""
        rows = []
        for sh in self._shards:
            with sh.lock:
                rows.extend((rec[0], k) for k, rec in sh.entries.items())
        heapq.heapify(rows)
        with self._seq_lock:
            self._recency = rows

    def _evict(self):
        """Remove globally-LRU entries until the cap holds. Serialized under
        the evict lock; the stamp re-check when popping makes a concurrent
        recency refresh win over an in-flight eviction (its fresher stamp is
        still in the heap)."""
        with self._evict_lock:
            # compact the lazy heap when stale stamps dominate (a store that
            # never evicts would otherwise accumulate one stamp per access)
            with self._seq_lock:
                oversized = len(self._recency) > max(64, 8 * self._count)
            if oversized:
                self._rebuild_recency()
            rebuilt = False
            while True:
                with self._seq_lock:
                    if self._count <= self.max_entries:
                        return
                    stamp = (heapq.heappop(self._recency)
                             if self._recency else None)
                if stamp is None:
                    if rebuilt:
                        return                    # defensive: can't progress
                    self._rebuild_recency()
                    rebuilt = True
                    continue
                seq, key = stamp
                sh = self._shard(key)
                with sh.lock:
                    rec = sh.entries.get(key)
                    if rec is None or rec[0] != seq:
                        continue                  # stale stamp; pop the next
                    entry = sh.entries.pop(key)[1]
                    with self._seq_lock:
                        self._count -= 1
                for fam in _entry_index_keys(entry):
                    self._unindex_family(key, fam)
                self.evictions += 1
                rebuilt = False                   # progress: allow re-repair

    # ------------------------------------------------------------------
    def _snapshot(self) -> List[tuple]:
        """(seq, key, entry) across all shards, LRU->MRU."""
        rows: List[tuple] = []
        for sh in self._shards:
            with sh.lock:
                rows.extend((rec[0], k, rec[1])
                            for k, rec in sh.entries.items())
        rows.sort(key=lambda r: r[0])
        return rows

    def flush(self):
        if not self.path:
            return
        with self._io_lock:
            entries = {k: e for _, k, e in self._snapshot()}
            self.path.parent.mkdir(parents=True, exist_ok=True)
            blob = json.dumps({"version": STORE_VERSION,
                               "entries": entries}, indent=2)
            tmp = self.path.with_name(self.path.name + ".tmp")
            tmp.write_text(blob)
            os.replace(tmp, self.path)

    # ------------------------------------------------------------------
    def _get_entry(self, key: str) -> Optional[Dict[str, Any]]:
        """Entry fetch *without* an LRU refresh (family ranking reads)."""
        sh = self._shard(key)
        with sh.lock:
            rec = sh.entries.get(key)
            return rec[1] if rec is not None else None

    def _ranked_family(self, family_key: str) -> List[tuple]:
        """``(exact_key, entry)`` members ranked deterministically: best
        recorded speedup first, exact key as tiebreak. Recency is NOT used —
        under a concurrent engine it reflects thread completion timing,
        which must never leak into which neighbor seeds a later run."""
        with self._family_lock:
            keys = list(self._family.get(family_key, []))
        members = []
        for key in keys:
            entry = self._get_entry(key)
            if entry is not None:
                members.append((key, entry))

        def rank(item):
            key, e = item
            orig = float(e.get("original_time") or 0.0)
            opt = float(e.get("optimized_time") or 0.0)
            speedup = orig / opt if orig > 0 and opt > 0 else 1.0
            return (-speedup, key)
        return sorted(members, key=rank)

    def get_family(self, family_key: str,
                   exclude: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Best-ranked family member whose exact key is not ``exclude``
        (the requester's own key — a diverged exact entry must not be
        handed back as its own transfer seed)."""
        for key, entry in self._ranked_family(family_key):
            if key != exclude:
                return entry
        return None

    def family_members(self, family_key: str) -> List:
        """Ranked ``(exact_key, transform_log)`` snapshot of a family
        (see :meth:`_ranked_family`). The engine freezes these per
        scheduling phase so transfer seeding does not depend on which
        concurrent job finished first."""
        return [(k, list(e.get("transform_log", [])))
                for k, e in self._ranked_family(family_key)]

    def ladder_members(self, ladder: Sequence[Tuple[str, str]],
                       dims: Optional[Sequence[int]] = None) -> List:
        """Graded neighbor selection: ``(exact_key, transform_log)`` pairs
        walking the family-key ladder finest tier first (same dims > same
        aspect ratios > same ranks), deduped by exact key. Within a tier,
        neighbors rank by (dim log-distance asc, transform-log length desc,
        recorded speedup desc, exact key asc) — the closest, richest
        trajectory seeds first; entries recorded before dims were stored
        rank last in their tier (distance ``inf``) but are never dropped.
        Deterministic like :meth:`family_members`: recency never
        participates, so concurrent completion order can't leak into which
        neighbor seeds a later run."""
        seen = set()
        out = []
        for _tier, fam_key in ladder:
            with self._family_lock:
                keys = list(self._family.get(fam_key, []))
            members = []
            for key in keys:
                if key in seen:
                    continue
                entry = self._get_entry(key)
                if entry is not None:
                    members.append((key, entry))

            def rank(item):
                key, e = item
                dist = (dims_log_distance(dims, e.get("dims"))
                        if dims is not None else 0.0)
                orig = float(e.get("original_time") or 0.0)
                opt = float(e.get("optimized_time") or 0.0)
                speedup = orig / opt if orig > 0 and opt > 0 else 1.0
                log_len = len(e.get("transform_log") or [])
                return (dist, -log_len, -speedup, key)

            for key, e in sorted(members, key=rank):
                seen.add(key)
                out.append((key, list(e.get("transform_log", []))))
        return out

    # ------------------------------------------------------------------
    def family_sizes(self) -> Dict[str, int]:
        with self._family_lock:
            return {k: len(v) for k, v in self._family.items()}

    def __len__(self) -> int:
        with self._seq_lock:
            return self._count

    def clear(self):
        # atomic vs concurrent put/get: hold EVERY shard lock (acquired in
        # index order — the one sanctioned multi-shard acquisition, see the
        # lock-ordering note up top) while zeroing entries and the count, so
        # an interleaved put can never leave them disagreeing
        with self._evict_lock:
            for sh in self._shards:
                sh.lock.acquire()
            try:
                for sh in self._shards:
                    sh.entries.clear()
                with self._family_lock:
                    self._family.clear()
                with self._seq_lock:
                    self._count = 0
                    self._recency.clear()
            finally:
                for sh in reversed(self._shards):
                    sh.lock.release()
            if self.path and self.path.exists():
                self.path.unlink()


# PR-1 name: the flat memoizer this store replaced. Kept as an alias so
# drivers and tests written against the old surface keep working.
ResultCache = ResultStore
