"""Transfer-aware result store: the two-level index behind the fleet engine.

PR 1's flat ``ResultCache`` was a pure memoizer — exact structural
fingerprint in, recorded :class:`TransformLog` out. This subsystem turns it
into the paper's "consistent optimization patterns" transfer mechanism with
two index levels:

* **Exact index** — fingerprint of (graph, schedule, spec, tolerances,
  policy) *plus the KB content hash* (folded in by the engine). A hit means
  the recorded winning sequence can be replayed verbatim and cross-checked
  for bit-identity. Because the KB hash participates, editing any KB YAML
  invalidates replay instead of pinning a stale sequence forever.

* **Family index** — rank-abstracted fingerprint
  (:func:`repro.ir.fingerprint.fingerprint_family`): same builder, different
  dims collide. On an exact miss with a family hit the engine *transfers*:
  the neighbor's log seeds the stage loop as a speculative warm start, each
  step re-verified on the real shapes. Family lookups are not KB-versioned —
  re-verification makes stale seeds safe, merely less effective.

On-disk format (version 2)::

    {"version": 2,
     "entries": {"<exact_key>": {"family": "<family_key>",
                                 "transform_log": [...],
                                 "canonical_schedule": [...],
                                 "original_time": ..., "optimized_time": ...,
                                 "clamped": false, "name": "..."}}}

Entries are kept in LRU order (dict order == recency; JSON round-trips it).
Loads are *tolerant*: corrupt JSON or an unknown ``version`` discards the
file and starts empty rather than crashing the driver. Writes are *atomic*:
serialized to a sibling tmp file, then ``os.replace``'d into place, so a
crash mid-flush can never leave a torn file. Eviction drops the
least-recently-used entry once ``max_entries`` is exceeded; the family index
is maintained alongside.
"""

from __future__ import annotations

import json
import logging
import os
import pathlib
import threading
from typing import Any, Dict, List, Optional

log = logging.getLogger(__name__)

STORE_VERSION = 2


class ResultStore:
    """Two-level (exact + family) LRU store of winning transform sequences.

    All access is lock-guarded for the engine's worker pool. ``get``/``put``
    keep the PR-1 ``ResultCache`` surface (the engine and older tests use
    them), extended with the family index and eviction.
    """

    def __init__(self, path: Optional[pathlib.Path] = None,
                 max_entries: int = 512):
        self.path = pathlib.Path(path) if path else None
        self.max_entries = max(1, int(max_entries))
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._family: Dict[str, List[str]] = {}   # family_key -> MRU-last keys
        self._lock = threading.Lock()
        self.evictions = 0
        if self.path and self.path.exists():
            self._load()

    # ------------------------------------------------------------------
    def _load(self):
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            log.warning("result store %s is corrupt (%s); starting empty",
                        self.path, e)
            return
        if not isinstance(data, dict) or data.get("version") != STORE_VERSION:
            log.warning("result store %s has version %r (want %d); discarded",
                        self.path, data.get("version") if isinstance(data, dict)
                        else None, STORE_VERSION)
            return
        entries = data.get("entries", {})
        if not isinstance(entries, dict):
            return
        for key, entry in entries.items():
            if not isinstance(entry, dict):
                continue
            self._entries[key] = entry
            self._index_family(key, entry.get("family"))
        # honor this instance's cap even against a larger on-disk file
        # (a replay-only run would otherwise never reach put's eviction)
        self._evict_locked()

    def _index_family(self, key: str, family: Optional[str]):
        if family:
            keys = self._family.setdefault(family, [])
            if key in keys:
                keys.remove(key)
            keys.append(key)

    # ------------------------------------------------------------------
    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Exact lookup. A hit refreshes the entry's LRU recency."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries[key] = self._entries.pop(key)   # move to MRU
                self._index_family(key, entry.get("family"))
            return entry

    def _ranked_family_locked(self, family_key: str) -> List[str]:
        """Family members ranked deterministically: best recorded speedup
        first, exact key as tiebreak. Insertion (MRU) order is NOT used —
        under a concurrent engine it reflects thread completion timing,
        which must never leak into which neighbor seeds a later run."""
        def rank(key: str):
            e = self._entries[key]
            orig = float(e.get("original_time") or 0.0)
            opt = float(e.get("optimized_time") or 0.0)
            speedup = orig / opt if orig > 0 and opt > 0 else 1.0
            return (-speedup, key)
        return sorted((k for k in self._family.get(family_key, [])
                       if k in self._entries), key=rank)

    def get_family(self, family_key: str,
                   exclude: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Best-ranked family member whose exact key is not ``exclude``
        (the requester's own key — a diverged exact entry must not be
        handed back as its own transfer seed)."""
        with self._lock:
            for key in self._ranked_family_locked(family_key):
                if key != exclude:
                    return self._entries[key]
            return None

    def put(self, key: str, entry: Dict[str, Any],
            family: Optional[str] = None, flush: bool = True):
        """Insert/refresh an entry. ``family`` threads the transfer index;
        ``flush=False`` defers the disk write (the engine batches inserts and
        flushes once per ``run_batch``)."""
        with self._lock:
            if family:
                entry = dict(entry)
                entry["family"] = family
            old = self._entries.pop(key, None)
            if old is not None:
                # re-put under a different (or no) family: drop the stale
                # index entry so get_family never serves a disowned key
                old_fam = old.get("family")
                if old_fam and old_fam != entry.get("family"):
                    keys = self._family.get(old_fam, [])
                    if key in keys:
                        keys.remove(key)
                    if not keys:
                        self._family.pop(old_fam, None)
            self._entries[key] = entry
            self._index_family(key, entry.get("family"))
            self._evict_locked()
            if flush:
                self._write_locked()

    def _evict_locked(self):
        while len(self._entries) > self.max_entries:
            key = next(iter(self._entries))               # LRU = oldest
            entry = self._entries.pop(key)
            fam = entry.get("family")
            if fam and fam in self._family:
                keys = self._family[fam]
                if key in keys:
                    keys.remove(key)
                if not keys:
                    del self._family[fam]
            self.evictions += 1

    # ------------------------------------------------------------------
    def flush(self):
        with self._lock:
            self._write_locked()

    def _write_locked(self):
        if not self.path:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        blob = json.dumps({"version": STORE_VERSION,
                           "entries": self._entries}, indent=2)
        tmp = self.path.with_name(self.path.name + ".tmp")
        tmp.write_text(blob)
        os.replace(tmp, self.path)

    def family_members(self, family_key: str) -> List:
        """Ranked ``(exact_key, transform_log)`` snapshot of a family
        (see :meth:`_ranked_family_locked`). The engine freezes these per
        scheduling phase so transfer seeding does not depend on which
        concurrent job finished first."""
        with self._lock:
            return [(k, list(self._entries[k].get("transform_log", [])))
                    for k in self._ranked_family_locked(family_key)]

    # ------------------------------------------------------------------
    def family_sizes(self) -> Dict[str, int]:
        with self._lock:
            return {k: len(v) for k, v in self._family.items()}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._family.clear()
            if self.path and self.path.exists():
                self.path.unlink()


# PR-1 name: the flat memoizer this store replaced. Kept as an alias so
# drivers and tests written against the old surface keep working.
ResultCache = ResultStore
