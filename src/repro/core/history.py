"""Optimization history (paper §VIII future work, implemented).

(Stage, pattern_id) outcomes are recorded per run. The history is the
*warm-start provider* for the stage scheduler: priors mined from past
records reorder each stage proposer's candidates so historically productive
patterns are tried first on future kernels ("learning from optimization
history" as few-shot priority rather than free generation).

Two prior policies (``ForgeConfig.prior_policy``):

``"counts"``  — the original flat success counts. :meth:`snapshot_priors`
                returns a :class:`PriorSnapshot` whose *Mapping* interface
                is exactly the legacy ``{pattern_id: successes}`` dict, so
                every pre-existing consumer (and candidate ordering) is
                bit-exact with the old behavior.
``"mined"``   — per-(stage, pattern) statistics: success rate, mean
                log-speedup, mean iterations-to-accept. The scheduler turns
                these into a scalar score per candidate
                (:meth:`PriorSnapshot.score`).

Persistence is append-only JSONL — one record per line, appended under the
lock — instead of rewriting the whole file per record. Files written by the
old format (a single JSON object ``{"records": [...]}``) are detected on
load and transparently migrated to JSONL on the first write.

Thread-safety: the fleet engine records from concurrent workers, so all
mutation happens under a lock. ``snapshot_priors`` returns a frozen
snapshot — the engine freezes one per batch so serial and concurrent runs
see identical candidate orderings regardless of completion order. Mined
statistics are folded over records in a canonical sort order, so float sums
cannot depend on the (backend-dependent) order records arrived in.
"""

from __future__ import annotations

import json
import math
import pathlib
import threading
from collections import defaultdict
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

PRIOR_POLICIES = ("counts", "mined")

# Mined-score weights: success rate dominates, log-speedup rewards patterns
# that win big, iterations-to-accept penalizes patterns that historically
# needed many proposals before landing.
_W_RATE = 1.0
_W_LOG_SPEEDUP = 0.5
_W_ITERATIONS = 0.05


def _canonical_record_order(records: Iterable[dict]) -> List[dict]:
    """Records sorted by their canonical JSON serialization. Mined stats
    fold floating-point sums over this order, so the snapshot is identical
    no matter which backend (and completion order) produced the records."""
    return sorted(records,
                  key=lambda r: json.dumps(r, sort_keys=True, default=str))


class PatternStats:
    """Accumulated outcomes for one (stage, pattern_id) cell."""

    __slots__ = ("attempts", "successes", "log_speedup_sum", "iterations_sum")

    def __init__(self):
        self.attempts = 0
        self.successes = 0
        self.log_speedup_sum = 0.0
        self.iterations_sum = 0

    @property
    def success_rate(self) -> float:
        return self.successes / self.attempts if self.attempts else 0.0

    @property
    def mean_log_speedup(self) -> float:
        return (self.log_speedup_sum / self.successes
                if self.successes else 0.0)

    @property
    def mean_iterations(self) -> float:
        return (self.iterations_sum / self.successes
                if self.successes else 0.0)

    def to_dict(self) -> dict:
        return {"attempts": self.attempts, "successes": self.successes,
                "log_speedup_sum": self.log_speedup_sum,
                "iterations_sum": self.iterations_sum}

    @classmethod
    def from_dict(cls, d: dict) -> "PatternStats":
        s = cls()
        s.attempts = int(d.get("attempts", 0))
        s.successes = int(d.get("successes", 0))
        s.log_speedup_sum = float(d.get("log_speedup_sum", 0.0))
        s.iterations_sum = int(d.get("iterations_sum", 0))
        return s

    def __eq__(self, other):
        if not isinstance(other, PatternStats):
            return NotImplemented
        return self.to_dict() == other.to_dict()

    def __repr__(self):
        return (f"PatternStats(attempts={self.attempts}, "
                f"successes={self.successes})")


class PriorSnapshot(Mapping):
    """Batch-frozen prior. As a Mapping it IS the legacy flat success-count
    dict (``snapshot["pat_x"]`` == number of successes), which keeps every
    counts-mode consumer bit-exact; the mined statistics live alongside and
    are reached through :meth:`score`."""

    def __init__(self, counts: Dict[str, int],
                 stats: Dict[Tuple[str, str], PatternStats],
                 policy: str = "counts"):
        if policy not in PRIOR_POLICIES:
            raise ValueError(f"unknown prior policy {policy!r}; "
                             f"expected one of {PRIOR_POLICIES}")
        self._counts = dict(counts)
        self._stats = dict(stats)
        self.policy = policy

    # -- Mapping interface: the legacy counts dict, bit-exact ------------
    def __getitem__(self, key: str) -> int:
        return self._counts[key]

    def __iter__(self):
        return iter(self._counts)

    def __len__(self) -> int:
        return len(self._counts)

    def __bool__(self) -> bool:
        # Truthiness gates warm-start wrapping (``if priors:``): an empty
        # history must stay a passthrough under both policies.
        return bool(self._counts) or bool(self._stats)

    def __eq__(self, other):
        if isinstance(other, PriorSnapshot):
            return (self._counts == other._counts
                    and self._stats == other._stats
                    and self.policy == other.policy)
        if isinstance(other, dict):
            # Legacy comparisons (tests assert snapshot == snapshot and
            # historically snapshot == dict) see the counts view.
            return self._counts == other
        return NotImplemented

    def __repr__(self):
        return (f"PriorSnapshot(policy={self.policy!r}, "
                f"patterns={len(self._counts)}, cells={len(self._stats)})")

    # -- mined statistics ------------------------------------------------
    def stats(self, stage: str, pattern_id: str) -> Optional[PatternStats]:
        return self._stats.get((stage, pattern_id))

    def score(self, stage: str, pattern_id: str) -> float:
        """Scalar mined prior for one candidate: higher is better. 0.0 for
        never-tried patterns (they rank on the cost model alone)."""
        s = self._stats.get((stage, pattern_id))
        if s is None or not s.attempts:
            return 0.0
        return (_W_RATE * s.success_rate
                + _W_LOG_SPEEDUP * s.mean_log_speedup
                - _W_ITERATIONS * s.mean_iterations)

    def to_dict(self) -> dict:
        """JSON-safe form (process-backend wire; see job_codec)."""
        return {
            "policy": self.policy,
            "counts": dict(self._counts),
            "stats": [[stage, pid, st.to_dict()]
                      for (stage, pid), st in sorted(self._stats.items())],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PriorSnapshot":
        stats = {(stage, pid): PatternStats.from_dict(st)
                 for stage, pid, st in d.get("stats", [])}
        return cls(d.get("counts", {}), stats, d.get("policy", "counts"))


def _fold_stats(records: Iterable[dict]) -> Dict[Tuple[str, str], PatternStats]:
    """Mined statistics over a record set, folded in canonical order.

    Success rate needs attempt counts per pattern, not just wins: each
    record carries the ``tried`` list of pattern_ids the stage proposed
    before (and including) the accepted one. Legacy records without the
    field degrade to counting only the accepted pattern. Records with an
    empty ``pattern_id`` AND no tried list contribute nothing (the
    "stop counting empty-pattern records" rule)."""
    stats: Dict[Tuple[str, str], PatternStats] = defaultdict(PatternStats)
    for rec in _canonical_record_order(records):
        stage = rec.get("stage", "")
        accepted = rec.get("pattern_id", "") or ""
        tried = rec.get("tried")
        if tried is None:
            tried = [accepted] if accepted else []
        for pid in tried:
            if not pid:
                continue
            stats[(stage, pid)].attempts += 1
        if rec.get("improved") and accepted:
            cell = stats[(stage, accepted)]
            cell.successes += 1
            speedup = rec.get("speedup")
            if speedup and speedup > 0:
                cell.log_speedup_sum += math.log(speedup)
            cell.iterations_sum += int(rec.get("iterations", 0) or 0)
    return dict(stats)


class History:
    def __init__(self, path: Optional[pathlib.Path] = None):
        self.path = pathlib.Path(path) if path else None
        self.records: List[dict] = []
        self.success_counts: Dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()
        # True while self.path holds the legacy whole-file JSON format; the
        # first write rewrites it as JSONL and clears the flag.
        self._needs_migration = False
        if self.path and self.path.exists():
            self.records = self._load_file(self.path)
            for r in self.records:
                if r.get("improved") and r.get("pattern_id"):
                    self.success_counts[r.get("pattern_id", "")] += 1

    # -- persistence (append-only JSONL with legacy-JSON migration) ------
    def _load_file(self, path: pathlib.Path) -> List[dict]:
        text = path.read_text()
        if not text.strip():
            return []
        # Legacy format: the whole file is one JSON object
        # {"records": [...]}. A JSONL file also starts with "{", so the
        # discriminator is a successful whole-file parse with a "records"
        # key (individual records never carry that key). Legacy files are
        # loadable as-is; the flag makes the first write migrate to JSONL.
        try:
            obj = json.loads(text)
        except json.JSONDecodeError:
            obj = None
        if isinstance(obj, dict) and "records" in obj:
            self._needs_migration = True
            return list(obj["records"])
        records = []
        for line in text.splitlines():
            line = line.strip()
            if line:
                records.append(json.loads(line))
        return records

    def _append_locked(self, new_records: List[dict]):
        """Persist ``new_records``; caller holds the lock. Appends JSONL
        lines, except when migrating a legacy file (or creating a new one),
        where the full record list is written once as JSONL."""
        if not self.path:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if self._needs_migration or not self.path.exists():
            with self.path.open("w") as f:
                for rec in self.records:
                    f.write(json.dumps(rec, sort_keys=True) + "\n")
            self._needs_migration = False
            return
        with self.path.open("a") as f:
            for rec in new_records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")

    # -- recording -------------------------------------------------------
    def record(self, problem: str, stage: str, pattern_id: str,
               improved: bool, speedup: Optional[float], iterations: int,
               tried: Optional[List[str]] = None):
        rec = {"problem": problem, "stage": stage, "pattern_id": pattern_id,
               "improved": improved, "speedup": speedup,
               "iterations": iterations}
        if tried is not None:
            rec["tried"] = [str(t) for t in tried if t]
        with self._lock:
            self.records.append(rec)
            if improved and pattern_id:
                self.success_counts[pattern_id] += 1
            self._append_locked([rec])

    def priority(self, pattern_id: str) -> int:
        return self.success_counts.get(pattern_id, 0)

    # ------------------------------------------------------------------
    def snapshot_priors(self, policy: str = "counts") -> PriorSnapshot:
        """Frozen prior snapshot, safe to share across a batch. The Mapping
        view is always the flat success counts (bit-exact legacy behavior);
        ``policy="mined"`` additionally activates the per-(stage, pattern)
        statistics consumers reach through :meth:`PriorSnapshot.score`."""
        with self._lock:
            counts = dict(self.success_counts)
            stats = _fold_stats(self.records) if policy == "mined" else {}
        return PriorSnapshot(counts, stats, policy)

    def merge(self, other: "History"):
        """Fold another history's records in (engine workers can record to
        private histories that merge at batch end)."""
        self.merge_records(other.records)

    def merge_records(self, records: List[dict]):
        """Fold raw record dicts in — the process-backend path: workers
        record to private histories, their records ride the results queue
        back, and the parent merges them here. Success counts are additive,
        so merge order never changes ``snapshot_priors``."""
        with self._lock:
            added = []
            for rec in records:
                self.records.append(rec)
                added.append(rec)
                if rec.get("improved") and rec.get("pattern_id"):
                    self.success_counts[rec["pattern_id"]] += 1
            self._append_locked(added)
