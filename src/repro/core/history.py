"""Optimization history (paper §VIII future work, implemented).

Successful (stage, pattern_id) transformations are recorded per run; proposers
can consult the success counts to prioritize historically productive patterns
on future kernels ("learning from optimization history" as few-shot priority
rather than free generation).
"""

from __future__ import annotations

import json
import pathlib
from collections import defaultdict
from typing import Dict, List, Optional


class History:
    def __init__(self, path: Optional[pathlib.Path] = None):
        self.path = pathlib.Path(path) if path else None
        self.records: List[dict] = []
        self.success_counts: Dict[str, int] = defaultdict(int)
        if self.path and self.path.exists():
            data = json.loads(self.path.read_text())
            self.records = data.get("records", [])
            for r in self.records:
                if r.get("improved"):
                    self.success_counts[r.get("pattern_id", "")] += 1

    def record(self, problem: str, stage: str, pattern_id: str,
               improved: bool, speedup: Optional[float], iterations: int):
        rec = {"problem": problem, "stage": stage, "pattern_id": pattern_id,
               "improved": improved, "speedup": speedup,
               "iterations": iterations}
        self.records.append(rec)
        if improved:
            self.success_counts[pattern_id] += 1
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self.path.write_text(json.dumps({"records": self.records}, indent=2))

    def priority(self, pattern_id: str) -> int:
        return self.success_counts.get(pattern_id, 0)
