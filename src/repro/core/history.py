"""Optimization history (paper §VIII future work, implemented).

Successful (stage, pattern_id) transformations are recorded per run. The
history is the *warm-start provider* for the stage scheduler: success-count
priors reorder each stage proposer's candidates so historically productive
patterns are tried first on future kernels ("learning from optimization
history" as few-shot priority rather than free generation).

Thread-safety: the fleet engine records from concurrent workers, so all
mutation happens under a lock. ``snapshot_priors`` returns an immutable-by-
convention copy — the engine freezes one snapshot per batch so serial and
concurrent runs see identical candidate orderings regardless of completion
order.
"""

from __future__ import annotations

import json
import pathlib
import threading
from collections import defaultdict
from typing import Dict, List, Optional


class History:
    def __init__(self, path: Optional[pathlib.Path] = None):
        self.path = pathlib.Path(path) if path else None
        self.records: List[dict] = []
        self.success_counts: Dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()
        if self.path and self.path.exists():
            data = json.loads(self.path.read_text())
            self.records = data.get("records", [])
            for r in self.records:
                if r.get("improved") and r.get("pattern_id"):
                    self.success_counts[r.get("pattern_id", "")] += 1

    def record(self, problem: str, stage: str, pattern_id: str,
               improved: bool, speedup: Optional[float], iterations: int):
        rec = {"problem": problem, "stage": stage, "pattern_id": pattern_id,
               "improved": improved, "speedup": speedup,
               "iterations": iterations}
        with self._lock:
            self.records.append(rec)
            if improved and pattern_id:
                self.success_counts[pattern_id] += 1
            if self.path:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                self.path.write_text(json.dumps({"records": self.records},
                                                indent=2))

    def priority(self, pattern_id: str) -> int:
        return self.success_counts.get(pattern_id, 0)

    # ------------------------------------------------------------------
    def snapshot_priors(self) -> Dict[str, int]:
        """Frozen copy of the success counts, safe to share across a batch."""
        with self._lock:
            return dict(self.success_counts)

    def merge(self, other: "History"):
        """Fold another history's records in (engine workers can record to
        private histories that merge at batch end)."""
        self.merge_records(other.records)

    def merge_records(self, records: List[dict]):
        """Fold raw record dicts in — the process-backend path: workers
        record to private histories, their records ride the results queue
        back, and the parent merges them here. Success counts are additive,
        so merge order never changes ``snapshot_priors``."""
        with self._lock:
            for rec in records:
                self.records.append(rec)
                if rec.get("improved") and rec.get("pattern_id"):
                    self.success_counts[rec["pattern_id"]] += 1
            if self.path:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                self.path.write_text(json.dumps({"records": self.records},
                                                indent=2))
