"""Distributed worker fleet: the ``execution_backend="remote"`` executor.

A :class:`FleetCoordinator` owns a listening socket plus a shared task
queue; N ``forge-worker`` processes (``repro.core.remote_worker``) —
spawned locally against the loopback address, launched by hand on other
hosts, or both — connect, complete the version/policy handshake
(:mod:`repro.core.remote`), and pull tasks. The task/event shapes are
exactly the tagged tuples of the process backend (``("keys", idx,
wire)`` / ``("job", idx, ...)`` down; ``("keys" | "stage" | "result" |
"error", idx, ...)`` up), so :class:`RemoteExecutor` is the process
executor with TCP in place of multiprocessing queues — and the parent
engine stays the single owner of store/stats/history, which is what
keeps ``serial == thread == process == remote`` result-equivalence.

Robustness model:

* **Worker loss** is detected by connection EOF/reset or by a missed
  heartbeat window (the coordinator pings every ``fleet_heartbeat_s``;
  a worker silent for ``fleet_heartbeat_timeout_s`` is declared lost).
* **Re-dispatch** — a lost worker's in-flight task goes back on the
  queue and runs on a surviving worker. This is idempotent by
  construction: workers are stateless between tasks, the parent merges
  each task's result exactly once (duplicate results are dropped), and
  every task/event frame carries a run id so events from an aborted run
  can never leak into a later one. Stage events from a job that was
  re-dispatched mid-run are delivered at-least-once (the re-run repeats
  them); results and store/stat/history merges stay exactly-once.
* **Drain** — :meth:`FleetCoordinator.close` with ``graceful=True``
  waits for the active run to finish its queued work, then sends every
  worker a ``shutdown`` frame and reaps spawned processes.
"""

from __future__ import annotations

import base64
import collections
import os
import pathlib
import pickle
import queue as queue_mod
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import job_codec, remote
from repro.core import journal as journal_mod
from repro.core.engine import fold_worker_result
from repro.core.faults import (FaultPlan, InjectedCrash,
                               deterministic_backoff)

__all__ = ["FleetError", "FleetCoordinator", "RemoteExecutor"]

_HANDSHAKE_TIMEOUT_S = 30.0


class FleetError(RuntimeError):
    """Fleet-level failure: no workers, a worker job raised, or the
    coordinator is closed."""


class _Worker:
    """Coordinator-side record of one connected worker."""

    _next_id = 0

    def __init__(self, sock: socket.socket, addr, pid, host):
        _Worker._next_id += 1
        self.id = _Worker._next_id
        self.sock = sock
        self.addr = addr
        self.pid = pid
        self.host = host
        self.send_lock = threading.Lock()
        self.alive = True
        self.inflight: Optional[Tuple[int, tuple]] = None  # (run_id, task)
        self.last_seen = time.monotonic()
        self.last_ping = 0.0

    def __repr__(self):
        return f"<worker #{self.id} pid={self.pid} {self.host}>"


class FleetCoordinator:
    """Owns the fleet: listener, worker registry, shared task queue.

    One ``run_tasks`` call is active at a time (the run lock); within a
    run, idle workers are assigned tasks as events drain, results are
    delivered through callbacks in arrival order, and lost workers'
    tasks are re-queued. The coordinator never executes jobs itself —
    it is pure dispatch, so the engine on top of it remains the single
    owner of every piece of shared state.
    """

    def __init__(self, pipeline, config, spawn_workers: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 journal_path: Optional[str] = None):
        self.pipeline = pipeline
        self.config = config
        self.spawn_workers = spawn_workers
        self.heartbeat_s = config.fleet_heartbeat_s
        self.heartbeat_timeout_s = config.fleet_heartbeat_timeout_s
        self.connect_timeout_s = config.fleet_connect_timeout_s
        self.max_respawns = config.fleet_max_respawns
        self._bind = remote.parse_address(config.fleet_address
                                          or "127.0.0.1:0")
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._workers: List[_Worker] = []
        self._procs: List[subprocess.Popen] = []
        self._events: "queue_mod.Queue" = queue_mod.Queue()
        self._lock = threading.Lock()
        self._run_lock = threading.Lock()
        self._run_id = 0
        self._closed = False
        self._config_frame_cache: Optional[dict] = None
        self._worker_env_cache: Optional[dict] = None
        self._spawn_count = 0           # worker index (fault targeting)
        self._respawn_attempts = 0
        self._dispatch_logged: set = set()  # idxs journaled this run
        # fault plan: explicit arg wins; else the config's JSON spec
        # (how a remote-backend engine threads faults down to its fleet)
        if fault_plan is None and config.fault_spec is not None:
            fault_plan = FaultPlan.from_json(config.fault_spec)
        self._fault_plan = fault_plan
        # telemetry the tests and the service /stats endpoint read
        self.workers_joined = 0
        self.workers_lost = 0
        self.workers_rejected = 0
        self.tasks_redispatched = 0
        self.workers_respawned = 0
        self.tasks_recovered = 0
        # crash-safe dispatch journal: explicit arg wins, else the config
        # knob. Opening replays it — the last wave's dispatched-but-
        # incomplete tasks become _recovered_tasks for resume_pending().
        self._journal: Optional[journal_mod.Journal] = None
        self._recovered_tasks: List[tuple] = []
        path = journal_path or config.fleet_journal_path
        if path is not None:
            self._journal = journal_mod.Journal(path,
                                                fault_plan=self._fault_plan)
            self._replay_journal()

    def _replay_journal(self) -> None:
        """Recover the last journaled wave: every task dispatched but not
        completed before the crash must be re-dispatched. Earlier waves
        need nothing — they either finished (their completes are all
        present) or were superseded by the wave that followed."""
        dispatched: Dict[Any, tuple] = {}
        completed: set = set()
        last_run = None
        for rec in self._journal.records:
            if not isinstance(rec, dict):
                continue
            kind = rec.get("kind")
            if kind == "wave":
                last_run = rec.get("run")
                dispatched.clear()
                completed.clear()
            elif kind == "dispatch" and rec.get("run") == last_run:
                task = rec.get("task")
                if isinstance(task, tuple) and len(task) >= 2:
                    dispatched[task[1]] = task
            elif kind == "complete" and rec.get("run") == last_run:
                completed.add(rec.get("idx"))
        self._recovered_tasks = [dispatched[i] for i in sorted(dispatched)
                                 if i not in completed]
        self.tasks_recovered = len(self._recovered_tasks)
        if not self._recovered_tasks:
            self._journal.compact([])   # nothing in flight: start clean

    def resume_pending(self, on_stage: Optional[Callable] = None,
                       on_result: Optional[Callable] = None
                       ) -> Dict[int, Any]:
        """Re-dispatch the tasks recovered from the journal (the wave in
        flight when the previous coordinator died) and return their
        results, ``{idx: payload}``. No-op ``{}`` when nothing was
        recovered. One-shot: the recovered list is consumed."""
        tasks, self._recovered_tasks = self._recovered_tasks, []
        if not tasks:
            return {}
        return self.run_tasks(tasks, on_stage=on_stage,
                              on_result=on_result)

    def telemetry(self) -> Dict[str, int]:
        """Fleet counters in one JSON-safe view (chaos gate / dashboards)."""
        return {"workers_joined": self.workers_joined,
                "workers_lost": self.workers_lost,
                "workers_rejected": self.workers_rejected,
                "workers_respawned": self.workers_respawned,
                "tasks_redispatched": self.tasks_redispatched,
                "tasks_recovered": self.tasks_recovered}

    # -- lifecycle -----------------------------------------------------
    def start(self) -> "FleetCoordinator":
        if self._listener is not None:
            return self
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(self._bind)
        listener.listen(64)
        self._listener = listener
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="fleet-accept")
        self._accept_thread.start()
        if self.spawn_workers > 0:
            self._spawn_local(self.spawn_workers)
        return self

    @property
    def address(self) -> str:
        """The resolved ``host:port`` workers should ``--connect`` to."""
        if self._listener is None:
            raise FleetError("coordinator not started")
        host, port = self._listener.getsockname()[:2]
        if host == "0.0.0.0":
            host = socket.gethostname()
        return remote.format_address(host, port)

    @property
    def worker_count(self) -> int:
        with self._lock:
            return len(self._workers)

    def wait_for_workers(self, n: int, timeout: float = 60.0) -> None:
        """Block until at least *n* workers completed the handshake."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.worker_count >= n:
                return
            time.sleep(0.05)
        raise FleetError(
            f"only {self.worker_count}/{n} workers joined within {timeout}s")

    def drain(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: queued work finishes, then workers stop."""
        self.close(graceful=True, timeout=timeout)

    def close(self, graceful: bool = True, timeout: float = 30.0) -> None:
        if graceful:
            # the run lock serializes with run_tasks: taking it means the
            # active run has delivered every queued task before we drain
            with self._run_lock:
                self._shutdown(graceful=True, timeout=timeout)
        else:
            self._shutdown(graceful=False, timeout=timeout)

    def _shutdown(self, graceful: bool, timeout: float) -> None:
        self._closed = True
        if self._journal is not None:
            # close the handle only — never compact here: after an
            # injected (or real) mid-wave failure the journal is the one
            # authoritative copy of what was still in flight
            self._journal.close()
        listener, self._listener = self._listener, None
        if listener is not None:
            try:
                listener.close()
            except OSError:
                pass
        with self._lock:
            workers, self._workers = list(self._workers), []
            for w in workers:
                # deliberate shutdown, not a loss: the reader threads will
                # see EOF when we close the sockets below and must not
                # count these workers as lost
                w.alive = False
        for w in workers:
            if graceful:
                try:
                    with w.send_lock:
                        remote.send_frame(w.sock, {"type": "shutdown"})
                except OSError:
                    pass
            try:
                w.sock.close()
            except OSError:
                pass
        procs, self._procs = list(self._procs), []
        deadline = time.monotonic() + timeout
        for p in procs:
            try:
                p.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.terminate()
                try:
                    p.wait(timeout=5)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait()

    # -- worker intake -------------------------------------------------
    def _worker_env(self) -> dict:
        if self._worker_env_cache is None:
            import repro
            # repro is a namespace package (__file__ is None) — derive the
            # import root from its search path instead
            src_root = str(
                pathlib.Path(list(repro.__path__)[0]).resolve().parent)
            env = dict(os.environ)
            env["PYTHONPATH"] = (src_root + os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else src_root)
            self._worker_env_cache = env
        return self._worker_env_cache

    def _spawn_local(self, n: int) -> None:
        """Launch *n* loopback ``forge-worker`` processes against our own
        address — through the real CLI entrypoint, so a spawned local
        worker and a multi-host one are the same code path."""
        for _ in range(n):
            self._spawn_one(with_faults=True)

    def _spawn_one(self, with_faults: bool) -> None:
        """Launch one worker. The fault plan's worker faults ride down to
        exactly the spawned worker whose index matches ``worker_index``
        — and never to a respawned replacement (``with_faults=False``),
        or the replacement would just re-die on the same trigger."""
        with self._lock:
            idx = self._spawn_count
            self._spawn_count += 1
        cmd = [sys.executable, "-m", "repro.core.remote_worker",
               "--connect", self.address]
        if (with_faults and self._fault_plan is not None
                and self._fault_plan.has_worker_faults()
                and idx == self._fault_plan.worker_index):
            cmd += ["--fault-plan", self._fault_plan.to_json()]
        proc = subprocess.Popen(cmd, env=self._worker_env(),
                                stdout=subprocess.DEVNULL)
        with self._lock:
            self._procs.append(proc)

    def _maybe_respawn(self) -> None:
        """Auto-respawn after a worker loss: replace one spawned worker,
        up to ``fleet_max_respawns`` over the coordinator's lifetime,
        after a capped deterministic backoff (the ForgeClient.wait
        schedule). Fleets that spawned nothing never respawn — external
        workers' lifecycles aren't ours to manage."""
        with self._lock:
            if (self._closed or self.spawn_workers <= 0
                    or self._listener is None
                    or self._respawn_attempts >= self.max_respawns):
                return
            attempt = self._respawn_attempts
            self._respawn_attempts += 1
        seed = self._fault_plan.seed if self._fault_plan is not None else 0
        host, port = self._bind

        def respawner():
            time.sleep(deterministic_backoff(
                f"respawn:{host}:{port}:{seed}", attempt,
                base_s=0.05, cap_s=2.0))
            if self._closed:
                return
            self._spawn_one(with_faults=False)
            with self._lock:
                self.workers_respawned += 1

        threading.Thread(target=respawner, daemon=True,
                         name="fleet-respawn").start()

    def _config_frame(self) -> dict:
        if self._config_frame_cache is None:
            self._config_frame_cache = {
                "type": "config",
                "protocol_version": remote.PROTOCOL_VERSION,
                "wire_version": remote.WIRE_VERSION,
                "config": self.config.to_dict(),
                "kb": base64.b64encode(
                    pickle.dumps(self.pipeline.kb)).decode("ascii"),
                "policy_signature": self.pipeline.policy_signature(),
                "kb_content_hash": self.pipeline.kb.content_hash(),
                "heartbeat_s": self.heartbeat_s,
            }
        return self._config_frame_cache

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, addr = self._listener.accept()
            except (OSError, AttributeError):
                return  # listener closed
            threading.Thread(target=self._serve_conn, args=(sock, addr),
                             daemon=True, name="fleet-handshake").start()

    def _serve_conn(self, sock: socket.socket, addr) -> None:
        """Handshake one incoming connection; on success this thread
        becomes the worker's reader loop."""
        try:
            sock.settimeout(_HANDSHAKE_TIMEOUT_S)
            hello = remote.recv_frame(sock)
            reason = remote.validate_hello(hello)
            if reason is None and self._closed:
                reason = "fleet is draining"
            if reason is not None:
                self.workers_rejected += 1
                try:
                    remote.send_frame(sock, {"type": "reject",
                                             "reason": reason})
                finally:
                    sock.close()
                return
            remote.send_frame(sock, self._config_frame())
            ready = remote.recv_frame(sock)
            if not isinstance(ready, dict) or ready.get("type") != "ready":
                self.workers_rejected += 1
                sock.close()
                return
            frame = self._config_frame()
            if (ready.get("policy_signature") != frame["policy_signature"]
                    or ready.get("kb_content_hash")
                    != frame["kb_content_hash"]):
                self.workers_rejected += 1
                try:
                    remote.send_frame(sock, {
                        "type": "reject",
                        "reason": "policy signature / KB content hash "
                                  "mismatch after worker-side rebuild"})
                finally:
                    sock.close()
                return
            sock.settimeout(None)
        except (OSError, remote.RemoteProtocolError):
            try:
                sock.close()
            except OSError:
                pass
            return
        worker = _Worker(sock, addr, ready.get("pid"), ready.get("host"))
        with self._lock:
            if self._closed:
                sock.close()
                return
            self._workers.append(worker)
            self.workers_joined += 1
        self._events.put(("joined", worker, None))
        self._reader_loop(worker)

    def _reader_loop(self, worker: _Worker) -> None:
        while True:
            try:
                msg = remote.recv_frame(worker.sock)
            except (OSError, remote.RemoteProtocolError) as exc:
                self._mark_lost(worker, f"read failed: {exc}")
                return
            if msg is None:
                self._mark_lost(worker, "connection closed")
                return
            worker.last_seen = time.monotonic()
            if not isinstance(msg, dict):
                continue
            kind = msg.get("type")
            if kind == "event":
                event = msg.get("event")
                if event and event[0] in ("keys", "result", "error"):
                    # terminal for the worker's current task regardless of
                    # run id: the worker is idle again either way
                    with self._lock:
                        worker.inflight = None
                self._events.put(("event", worker, msg.get("run"), event))
            # "pong" needs nothing beyond the last_seen update above

    def _mark_lost(self, worker: _Worker, reason: str) -> None:
        with self._lock:
            if not worker.alive:
                return
            worker.alive = False
            if worker in self._workers:
                self._workers.remove(worker)
            self.workers_lost += 1
        try:
            worker.sock.close()
        except OSError:
            pass
        self._events.put(("lost", worker, reason))
        self._maybe_respawn()

    def _send(self, worker: _Worker, msg: dict) -> bool:
        try:
            with worker.send_lock:
                remote.send_frame(worker.sock, msg)
            return True
        except (OSError, remote.RemoteProtocolError) as exc:
            self._mark_lost(worker, f"send failed: {exc}")
            return False

    # -- dispatch ------------------------------------------------------
    def _dispatch(self, pending: collections.deque, run_id: int) -> bool:
        """Hand queued tasks to idle workers. Returns True if anything
        was dispatched (progress, for the no-worker deadline)."""
        did = False
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            if not pending:
                break
            with self._lock:
                if not w.alive or w.inflight is not None:
                    continue
                task = pending.popleft()
                w.inflight = (run_id, task)
            # journal the dispatch BEFORE the task frame leaves (WAL
            # ordering: a crash after send but before journal would
            # forget an in-flight task). First dispatch only — a
            # re-dispatch after worker loss is not a new fact.
            if self._journal is not None \
                    and task[1] not in self._dispatch_logged:
                self._dispatch_logged.add(task[1])
                self._journal.append(
                    journal_mod.dispatch_record(run_id, task))
            # a failed send marks the worker lost; the run loop's "lost"
            # handler re-queues the task off w.inflight — never clear it
            # here or a racing loss event would drop the task on the floor
            self._send(w, {"type": "task", "run": run_id, "task": task})
            did = True
        return did

    def _heartbeat(self) -> None:
        now = time.monotonic()
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            if now - w.last_seen > self.heartbeat_timeout_s:
                self._mark_lost(
                    w, f"no heartbeat for {now - w.last_seen:.1f}s")
            elif now - w.last_ping >= self.heartbeat_s:
                w.last_ping = now
                self._send(w, {"type": "ping"})

    def _has_live_workers(self) -> bool:
        with self._lock:
            return bool(self._workers)

    def run_tasks(self, tasks: Sequence[tuple],
                  on_stage: Optional[Callable] = None,
                  on_result: Optional[Callable] = None) -> Dict[int, Any]:
        """Run one wave of tagged tasks across the fleet; returns ``{idx:
        payload}`` for every task. ``on_stage(idx, job_name, record_wire)``
        streams stage events live; ``on_result(idx, payload)`` fires once
        per task as its terminal event arrives (arrival order — the same
        live folding the process backend does). Raises :class:`FleetError`
        if a worker job raised or no live worker remains for longer than
        ``fleet_connect_timeout_s``."""
        with self._run_lock:
            if self._closed:
                raise FleetError("fleet coordinator is closed")
            self._run_id += 1
            run_id = self._run_id
            self._dispatch_logged = set()
            if self._journal is not None:
                self._journal.append(
                    journal_mod.wave_record(run_id, len(tasks)))
            pending = collections.deque(tasks)
            results: Dict[int, Any] = {}
            want = len(tasks)
            now = time.monotonic()
            with self._lock:
                for w in self._workers:
                    # idle workers were silent between runs by design;
                    # restart their heartbeat windows
                    w.last_seen = now
            last_progress = now
            while len(results) < want:
                if self._dispatch(pending, run_id):
                    last_progress = time.monotonic()
                try:
                    item = self._events.get(
                        timeout=min(0.2, self.heartbeat_s / 2))
                except queue_mod.Empty:
                    self._heartbeat()
                    if (not self._has_live_workers()
                            and time.monotonic() - last_progress
                            > self.connect_timeout_s):
                        raise FleetError(
                            f"no live fleet workers for "
                            f"{self.connect_timeout_s:.0f}s "
                            f"({len(results)}/{want} tasks done, "
                            f"{self.workers_lost} lost, "
                            f"{self.workers_rejected} rejected)")
                    continue
                last_progress = time.monotonic()
                kind = item[0]
                if kind == "joined":
                    continue
                if kind == "lost":
                    _, worker, reason = item
                    with self._lock:
                        inflight, worker.inflight = worker.inflight, None
                    if (inflight is not None and inflight[0] == run_id
                            and inflight[1][1] not in results):
                        # idempotent re-dispatch: the task goes back on the
                        # queue; the worker never returned a result for it,
                        # so the re-run's result is the only one merged
                        pending.appendleft(inflight[1])
                        self.tasks_redispatched += 1
                    continue
                _, worker, event_run, event = item
                if event_run != run_id or not event:
                    continue  # stale event from an aborted run
                ekind, idx = event[0], event[1]
                if ekind == "stage":
                    if on_stage is not None:
                        on_stage(idx, event[2], event[3])
                elif ekind in ("keys", "result"):
                    if idx in results:
                        continue  # duplicate (merge once)
                    results[idx] = event[2]
                    if self._journal is not None:
                        # sync=False: losing a completion record only
                        # costs a safe (idempotent) re-run on recovery
                        self._journal.append(
                            journal_mod.complete_record(run_id, idx),
                            sync=False)
                    if (self._fault_plan is not None
                            and self._fault_plan.take_completion()):
                        raise InjectedCrash(
                            f"coordinator crash after journaling "
                            f"completion #{idx} (run {run_id})")
                    if on_result is not None:
                        on_result(idx, event[2])
                else:  # "error"
                    raise FleetError(
                        f"fleet worker task #{idx} failed "
                        f"(worker {worker!r}):\n{event[2]}")
            if self._journal is not None:
                # wave fully merged: nothing left to recover from it
                self._journal.compact([])
            return results


class RemoteExecutor:
    """The engine executor for ``execution_backend="remote"``: the
    process executor's dispatch shape (worker-side key computation,
    duplicate-exact-key waves, streamed stage events, parent-side result
    folding and history merge) over a :class:`FleetCoordinator`."""

    name = "remote"

    def __init__(self, engine):
        if engine.pipeline.llm is not None:
            raise ValueError(
                "execution_backend='remote' cannot ship a live LLM client "
                "to fleet workers; use the 'thread' backend")
        self.engine = engine
        cfg = engine.pipeline.config
        spawn = cfg.fleet_spawn_workers
        if spawn is None:
            spawn = max(1, engine.workers)
        self.fleet = FleetCoordinator(engine.pipeline, cfg,
                                      spawn_workers=spawn)
        self.fleet.start()
        self._wires: Optional[tuple] = None     # (id(jobs), [wire, ...])
        self._phase_lock = threading.Lock()

    # ------------------------------------------------------------------
    def compute_keys(self, jobs) -> List[tuple]:
        with self._phase_lock:
            try:
                wires = [job_codec.encode_job(job) for job in jobs]
                self._wires = (id(jobs), wires)
                out = self.fleet.run_tasks(
                    [("keys", i, wires[i]) for i in range(len(jobs))])
                return [tuple(out[i]) for i in range(len(jobs))]
            except Exception:
                self.close()
                raise

    # ------------------------------------------------------------------
    def run_phase(self, jobs, phase, keys, priors, seeds, results,
                  plan=None, on_stage=None, notify=None):
        with self._phase_lock:
            try:
                # duplicate exact keys run as a second wave, mirroring the
                # process backend: first occurrence computes, duplicates
                # replay the stored entry
                seen = set()
                waves: List[List[int]] = [[], []]
                for i in phase:
                    waves[1 if keys[i][0] in seen else 0].append(i)
                    seen.add(keys[i][0])
                for wave in waves:
                    if wave:
                        self._run_wave(jobs, wave, keys, priors, seeds,
                                       results, plan, on_stage=on_stage,
                                       notify=notify)
            except Exception:
                # same policy as the process pool: a failed wave leaves
                # in-flight state behind; tear the fleet down so the next
                # batch starts from a clean coordinator
                self.close()
                raise

    def _run_wave(self, jobs, wave, keys, priors, seeds, results, plan=None,
                  on_stage=None, notify=None):
        engine = self.engine
        wires = (self._wires[1] if self._wires
                 and self._wires[0] == id(jobs) else None)
        priors_wire = job_codec.encode_priors(priors)
        tasks = []
        for i in wave:
            exact_key, family_key = keys[i][0], keys[i][1]
            wire = wires[i] if wires else job_codec.encode_job(jobs[i])
            warm_wire = None
            if plan and plan.get(i) and engine.verify_shared is not None:
                items = [(key, val) for key in plan[i]
                         if (val := engine.verify_shared.get(key)) is not None]
                if items:
                    warm_wire = job_codec.encode_verify_slice(items)
            tasks.append(("job", i, wire, exact_key, family_key,
                          priors_wire, engine.cache.get(exact_key),
                          list(seeds.get(i, ())), warm_wire))
        history_records: Dict[int, List[dict]] = {}

        def stage_cb(idx, job_name, record_wire):
            hook = engine.pipeline.on_stage_complete
            if hook is None and on_stage is None:
                return
            record = job_codec.decode_stage_record(record_wire)
            if hook is not None:
                hook(job_name, record)
            if on_stage is not None:
                on_stage(idx, job_name, record)

        def result_cb(idx, payload):
            results[idx] = fold_worker_result(engine, jobs[idx], keys[idx],
                                              payload, notify=notify)
            history_records[idx] = payload["history"]

        self.fleet.run_tasks(tasks, on_stage=stage_cb, on_result=result_cb)
        # merge worker history deltas in submission order (additive counts,
        # deterministic record list) — identical to the process backend
        for i in sorted(history_records):
            engine.pipeline.history.merge_records(history_records[i])

    # ------------------------------------------------------------------
    def end_batch(self):
        self._wires = None

    def close(self):
        self._wires = None
        self.fleet.close(graceful=True, timeout=15.0)
