"""Trusted execution path for kernel programs.

``program_callable`` turns a :class:`KernelProgram` into a function the
verifier runs. This module — not the candidate — owns input generation,
weight seeding and dispatch (the paper's *kernel harness separation*, §VII-a):
a candidate is only a (graph, schedule) value; it cannot route execution back
to the oracle or touch the harness.

Pallas-impl groups are executed through the real kernels in interpret mode;
XLA-impl groups evaluate node-by-node with jnp. Mixed precision follows the
TPU pattern: external group inputs are stored/loaded in the schedule's
compute dtype, math runs in f32 (MXU: bf16 in, f32 accumulate).

``group_exec_signature`` must stay in lockstep with ``run_group``'s actual
dispatch: it is the *effective*-dispatch key of the verification fast path's
group memo and of the cross-job shared cache, so any new input that changes
what ``run_group`` computes (a template choice, a clamped block, a dtype
rule) must fold into the signature — and the batch planner's pre-executions
(:meth:`OptimizationEngine._plan_batch`) dispatch through these same
functions precisely so parent and worker derive bit-identical keys.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp

from repro.ir.graph import Graph, Node
from repro.ir.interpreter import op_impl
from repro.ir.schedule import FusionGroup, KernelProgram
from repro.kernels.epilogue import EpilogueOp
from repro.kernels.matmul_fused import matmul_fused, matmul_fused_naive
from repro.kernels.elementwise import elementwise_chain
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel


class ExecUnsupported(Exception):
    """A pallas impl was requested for a group with no kernel template."""


_EPILOGUE_UNARY = ("relu", "gelu", "silu", "swish", "sigmoid", "tanh", "mish",
                   "exp", "abs", "square", "neg", "softplus", "identity",
                   "dropout")
_EPILOGUE_BINARY = ("add", "sub", "mul", "div", "minimum", "maximum", "bias_add")
_EPILOGUE_SCALAR = ("scale", "add_scalar", "clamp_min", "clamp_max")
_RED_MAP = {"reduce_sum": "sum", "reduce_max": "max", "reduce_min": "min",
            "reduce_mean": "mean"}


def group_order(graph: Graph, groups: List[FusionGroup]) -> List[FusionGroup]:
    """Topological order over the group dependency DAG."""
    owner = {n: g.name for g in groups for n in g.nodes}
    deps: Dict[str, set] = {g.name: set() for g in groups}
    by_name = {g.name: g for g in groups}
    for g in groups:
        for n in g.nodes:
            for i in graph.node(n).inputs:
                o = owner.get(i)
                if o is not None and o != g.name:
                    deps[g.name].add(o)
    out, done = [], set()
    pending = list(groups)
    while pending:
        progressed = False
        for g in list(pending):
            if deps[g.name] <= done:
                out.append(g)
                done.add(g.name)
                pending.remove(g)
                progressed = True
        if not progressed:
            raise ValueError("cyclic group dependency")
    return out


# ----------------------------------------------------------------------
# template matching for pallas groups
# ----------------------------------------------------------------------

def _as_epilogue(graph: Graph, nodes: List[Node], produced: set,
                 start_value: str) -> Tuple[List[EpilogueOp], List[str]]:
    """Convert a linear elementwise chain into EpilogueOps. Returns
    (epilogue, external operand names). Raises ExecUnsupported on mismatch."""
    epilogue: List[EpilogueOp] = []
    operands: List[str] = []
    current = start_value
    for n in nodes:
        if current not in n.inputs:
            raise ExecUnsupported(f"epilogue node {n.name} does not consume the chain")
        others = [i for i in n.inputs if i != current]
        if n.op in _EPILOGUE_UNARY:
            if others:
                raise ExecUnsupported(f"unary {n.name} with extra inputs")
            if n.op not in ("identity", "dropout"):
                epilogue.append(EpilogueOp(n.op))
        elif n.op in _EPILOGUE_SCALAR:
            epilogue.append(EpilogueOp(n.op, value=float(n.attrs["value"])))
        elif n.op in _EPILOGUE_BINARY:
            if len(others) != 1:
                raise ExecUnsupported(f"binary {n.name} needs exactly one operand")
            src = graph.node(others[0])
            if src.op == "const":
                epilogue.append(EpilogueOp(n.op, value=float(src.attrs["value"])))
            elif others[0] in produced:
                raise ExecUnsupported(
                    f"binary {n.name} consumes an in-group intermediate")
            else:
                # operand order matters for sub/div: chain value must be lhs
                if n.inputs[0] != current and n.op in ("sub", "div"):
                    raise ExecUnsupported(f"{n.name}: chain value is rhs of {n.op}")
                epilogue.append(EpilogueOp(n.op, operand=others[0]))
                operands.append(others[0])
        else:
            raise ExecUnsupported(f"op {n.op} not fusable as epilogue")
        current = n.name
    return epilogue, operands


def _run_pallas_group(graph: Graph, group: FusionGroup, env: Dict[str, jnp.ndarray],
                      compute_dtype, interpret: bool = True) -> Dict[str, jnp.ndarray]:
    nodes = [graph.node(n) for n in group.nodes]
    produced = set(group.nodes)
    cfg = group.config
    naive = group.impl == "pallas_naive"

    def load(name: str) -> jnp.ndarray:
        return env[name].astype(compute_dtype)

    # template 1: single rmsnorm
    if len(nodes) == 1 and nodes[0].op == "rmsnorm":
        n = nodes[0]
        x = load(n.inputs[0])
        w = env[n.inputs[1]] if len(n.inputs) > 1 else jnp.ones(x.shape[-1], x.dtype)
        lead, d = x.shape[:-1], x.shape[-1]
        out = rmsnorm_kernel(x.reshape(-1, d), w, eps=n.attrs.get("eps", 1e-6),
                             interpret=interpret).reshape(*lead, d)
        return {n.name: out}

    # template 2: matmul (+ epilogue chain) (+ terminal row reduction)
    if nodes[0].op == "matmul" and len(nodes[0].shape) == 2:
        mm = nodes[0]
        chain = nodes[1:]
        reduction = None
        if chain and chain[-1].op in _RED_MAP:
            red = chain[-1]
            axes = tuple(ax % 2 for ax in red.attrs.get("axes", ()))
            if axes != (1,) or red.attrs.get("keepdims", False):
                raise ExecUnsupported("only row (axis=1) reductions fuse")
            reduction = _RED_MAP[red.op]
            chain = chain[:-1]
        epilogue, op_names = _as_epilogue(graph, chain, produced, mm.name)
        a = load(mm.inputs[0])
        b = load(mm.inputs[1])
        if mm.attrs.get("transpose_a"):
            a = a.T
        if mm.attrs.get("transpose_b"):
            b = b.T  # packed or not: numerics identical, cost model differs
        operands = {s: env[s].astype(compute_dtype) for s in op_names}
        m, k = a.shape
        n_ = b.shape[1]
        if naive:
            bm = min(cfg.block_m if cfg else 128, m)
            bn = min(cfg.block_n if cfg else 128, n_)
            bk = min(cfg.block_k if cfg else 128, k)
            out = matmul_fused_naive(a, b, block_m=bm, block_n=bn, block_k=bk,
                                     epilogue=epilogue, operands=operands,
                                     reduction=reduction, out_dtype=compute_dtype,
                                     interpret=interpret)
        else:
            c = cfg or type("C", (), {})()
            out = matmul_fused(
                a, b,
                block_m=min(getattr(c, "block_m", 128), m),
                block_n=min(getattr(c, "block_n", 128), n_),
                block_k=min(getattr(c, "block_k", 128), k),
                group_m=getattr(c, "group_m", 1),
                num_stages=getattr(c, "num_stages", 2),
                epilogue=epilogue, operands=operands, reduction=reduction,
                out_dtype=compute_dtype, interpret=interpret)
        last = group.nodes[-1]
        want_shape = graph.node(last).shape
        return {last: out.reshape(want_shape)}

    # template 3: pure elementwise chain
    if all(n.is_elementwise() for n in nodes):
        x_name = nodes[0].inputs[0]
        epilogue, op_names = _as_epilogue(graph, nodes, produced, x_name)
        x = load(x_name)
        lead, ccol = x.shape[:-1], x.shape[-1]
        operands = {s: env[s].astype(compute_dtype).reshape(-1, env[s].shape[-1])
                    if env[s].ndim == x.ndim else env[s].astype(compute_dtype)
                    for s in op_names}
        out = elementwise_chain(x.reshape(-1, ccol), epilogue, operands=operands,
                                out_dtype=compute_dtype, interpret=interpret)
        last = group.nodes[-1]
        return {last: out.reshape(graph.node(last).shape)}

    raise ExecUnsupported(
        f"group {group.name} ({[n.op for n in nodes]}) has no pallas template")


def _run_xla_group(graph: Graph, group: FusionGroup, env: Dict[str, jnp.ndarray],
                   compute_dtype) -> Dict[str, jnp.ndarray]:
    produced: Dict[str, jnp.ndarray] = {}

    def val(name: str) -> jnp.ndarray:
        if name in produced:
            return produced[name]
        v = env[name]
        if jnp.issubdtype(v.dtype, jnp.floating):
            # storage dtype at group boundary, f32 math inside
            return v.astype(compute_dtype).astype(jnp.float32)
        return v

    for name in group.nodes:
        n = graph.node(name)
        args = [val(i) for i in n.inputs]
        produced[name] = op_impl(n.op, n.attrs)(*args)
    # external results stored in compute dtype
    return {k: v.astype(compute_dtype) if jnp.issubdtype(v.dtype, jnp.floating) else v
            for k, v in produced.items()}


# ----------------------------------------------------------------------
def run_group(graph: Graph, group: FusionGroup, env: Dict[str, jnp.ndarray],
              compute_dtype, use_pallas: bool = True,
              interpret: bool = True) -> Dict[str, jnp.ndarray]:
    """Execute one fusion group against ``env`` — the single dispatch point
    shared by :func:`run_program` and the memoizing fast path
    (``repro.core.verify_cache``). Returns the name->array bindings the
    group contributes (pallas templates emit only their final node)."""
    if group.impl.startswith("pallas") and use_pallas:
        return _run_pallas_group(graph, group, env, compute_dtype, interpret)
    return _run_xla_group(graph, group, env, compute_dtype)


def group_exec_signature(graph: Graph, group: FusionGroup,
                         use_pallas: bool = True) -> tuple:
    """The *effective* dispatch parameters :func:`run_group` would hand the
    kernel templates — everything config-derived that can change the
    computed values, and nothing more. This is the config half of the fast
    path's group cache key (node ops/attrs/shapes are keyed separately), and
    it deliberately collapses distinct configs with identical effect: the
    templates clamp blocks to the operand dims, so on small ci shapes a
    (512,512,512) and a (1024,1024,1024) candidate execute identically and
    may share one cached run.

    MUST stay in lockstep with the template dispatch above: a template that
    starts reading a new config field has to fold it in here, which is why
    this lives in the executor and not next to the cache."""
    nodes = [graph.node(n) for n in group.nodes]
    if not (group.impl.startswith("pallas") and use_pallas):
        # the XLA runner reads only ops/attrs (already in the node payload)
        return ("xla",)
    cfg = group.config
    if len(nodes) == 1 and nodes[0].op == "rmsnorm":
        return ("rmsnorm",)                      # template 1 ignores cfg
    if nodes[0].op == "matmul" and len(nodes[0].shape) == 2:
        mm = nodes[0]
        a_shape = graph.node(mm.inputs[0]).shape
        b_shape = graph.node(mm.inputs[1]).shape
        m, k = ((a_shape[1], a_shape[0]) if mm.attrs.get("transpose_a")
                else (a_shape[0], a_shape[1]))
        n_ = b_shape[0] if mm.attrs.get("transpose_b") else b_shape[1]
        if group.impl == "pallas_naive":
            return ("matmul_naive",
                    min(cfg.block_m if cfg else 128, m),
                    min(cfg.block_n if cfg else 128, n_),
                    min(cfg.block_k if cfg else 128, k))
        return ("matmul",
                min(getattr(cfg, "block_m", 128) if cfg else 128, m),
                min(getattr(cfg, "block_n", 128) if cfg else 128, n_),
                min(getattr(cfg, "block_k", 128) if cfg else 128, k),
                getattr(cfg, "group_m", 1) if cfg else 1,
                getattr(cfg, "num_stages", 2) if cfg else 2)
    if all(n.is_elementwise() for n in nodes):
        return ("elementwise",)                  # template 3 ignores cfg
    # unknown shape (run_group would raise ExecUnsupported): key on the full
    # raw group description so nothing can alias
    return ("raw", group.impl,
            tuple(sorted(cfg.to_dict().items())) if cfg else None,
            tuple(sorted(group.operand_layouts.items())), group.prefetch)


def run_program(program: KernelProgram,
                inputs: Dict[str, jnp.ndarray],
                params: Dict[str, jnp.ndarray],
                use_pallas: bool = True,
                interpret: bool = True) -> Dict[str, jnp.ndarray]:
    graph = program.graph
    sched = program.schedule
    compute_dtype = jnp.dtype(sched.compute_dtype)
    env: Dict[str, jnp.ndarray] = {}
    for n in graph.toposorted():
        if n.op == "input":
            env[n.name] = inputs[n.name]
        elif n.op == "param":
            env[n.name] = params[n.name]
        elif n.op == "const":
            env[n.name] = jnp.asarray(n.attrs["value"], jnp.dtype(n.dtype))
    for g in group_order(graph, sched.groups):
        env.update(run_group(graph, g, env, compute_dtype,
                             use_pallas=use_pallas, interpret=interpret))
    return {o: env[o].astype(jnp.float32) for o in graph.outputs}


def program_callable(program: KernelProgram, params: Dict[str, jnp.ndarray],
                     use_pallas: bool = True):
    def fn(inputs: Dict[str, jnp.ndarray]):
        return run_program(program, inputs, params, use_pallas=use_pallas)
    return fn
