"""Four-level verification cascade (paper §IV-B-c).

``compile_and_verify`` is the single *tool* the CoVeR agent invokes. Each
level gates the next; the first failure returns a structured diagnostic that
becomes the agent's observation for the next refinement iteration:

  1. Syntax      — the candidate program validates and traces abstractly.
  2. Structure   — KB hardware constraints hold (block alignment, VMEM budget,
                   MXU minimums, f32 accumulation, dtype bans, ...); messages
                   carry remediation instructions, paper-style.
  3. Correctness — executed (real Pallas kernels, interpret mode) against the
                   seeded oracle outputs; allclose(rtol, atol) + NaN/Inf gates;
                   mismatch diagnostics include max-abs/mean/rel-diff and
                   exceed counts plus likely causes.
  4. Performance — the v5e roofline cost model must beat the incumbent. On
                   failure the agent receives both timings + TFLOPS +
                   alternative-strategy suggestions.

Returns the success sentinel only when all four pass.

With ``verify_fastpath`` enabled, levels 1-4 run against a
:class:`~repro.core.verify_cache.VerifySession` (memoized traces, structure
verdicts, group executions, costs) that may itself read through an
engine-owned cross-job :class:`~repro.core.verify_cache.SharedVerifyCache`.
``"check"`` mode extends its bit-identical contract down that shared layer:
besides cross-checking every report against the uncached cascade, each
shared-cache hit (a group execution or a positionally rebound oracle prep
seeded by *another job*) is byte-compared against a fresh local execution
before adoption, so a corrupt or colliding shared entry raises
:class:`VerifyFastpathDivergence` at the exact artifact that diverged
rather than surfacing as a numeric drift in some later verdict.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.context import ProblemContext
from repro.core.executor import ExecUnsupported, run_program
from repro.core.verify_cache import (VerifyFastpathDivergence, VerifySession,
                                     run_program_cached)
from repro.hw.specs import dtype_itemsize
from repro.ir.cost import CostModel
from repro.ir.schedule import KernelProgram
from repro.kb.loader import KnowledgeBase

SUCCESS = "VERIFIED: correct and faster — all checks passed"

# Acceptance threshold for the performance level: a candidate must beat the
# incumbent by this factor. Shared with the scheduler's cost-ranked early
# stop — a candidate whose roofline estimate can't clear this bar can never
# be accepted, so verifying it is pure waste.
MIN_SPEEDUP = 1.001


@dataclasses.dataclass
class VerifyReport:
    ok: bool
    level: str                   # syntax | structure | correctness | performance | success
    observation: str
    candidate_time: Optional[float] = None
    incumbent_time: Optional[float] = None
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    # cost-first screening skipped the correctness execution: the candidate
    # cannot beat the incumbent, so the expensive oracle comparison is
    # deferred until (and unless) the fallback extractor needs it
    correctness_deferred: bool = False

    @property
    def speedup(self) -> Optional[float]:
        if self.candidate_time and self.incumbent_time:
            return self.incumbent_time / self.candidate_time
        return None


# ----------------------------------------------------------------------
# level 2 checks, keyed by KB constraint check.type
# ----------------------------------------------------------------------

def _check_structure(program: KernelProgram, ctx: ProblemContext,
                     kb: KnowledgeBase) -> List[str]:
    errors: List[str] = []
    sched = program.schedule
    g = program.graph
    sub, lane = ctx.spec.min_tile(sched.compute_dtype)

    # dtype bans
    for c in kb.critical_constraints():
        if c.check.get("type") == "dtype_ban":
            banned = c.check.get("value", "float64")
            offenders = [n.name for n in g.toposorted() if str(n.dtype) == banned]
            if sched.compute_dtype == banned:
                offenders.append(f"schedule.compute_dtype={banned}")
            if offenders:
                errors.append(
                    f"INVALID dtype {banned} at {offenders[:4]}: {c.description.strip()} "
                    f"Fix: {c.correct}")

    for grp in sched.groups:
        cfg = grp.config
        if not grp.impl.startswith("pallas"):
            continue
        if cfg is None:
            errors.append(f"INVALID group {grp.name}: pallas impl without a "
                          f"PallasConfig. Fix: attach a config (hw query defaults).")
            continue
        if cfg.block_m <= 0 or cfg.block_n <= 0 or cfg.block_k <= 0:
            errors.append(f"INVALID blocks in {grp.name}: non-positive block size.")
            continue
        root = g.node(grp.root)
        if grp.impl == "pallas_blockspec":
            if cfg.block_n % lane or cfg.block_m % sub:
                errors.append(
                    f"INVALID block_shape=({cfg.block_m},{cfg.block_n}) in {grp.name}: "
                    f"must be multiples of the native ({sub},{lane}) tile at "
                    f"{sched.compute_dtype}. Valid examples: ({sub},{lane}), "
                    f"({sub*2},{lane}), ({sub*16},{lane*4}).")
            if root.op == "matmul" and cfg.block_k % lane:
                errors.append(
                    f"INVALID block_k={cfg.block_k} in {grp.name}: contraction tile "
                    f"must be a multiple of {lane} (MXU native).")
        # VMEM budget. Note: the naive kernel's whole-operand refs spilling to
        # HBM is a *performance* pathology (the cost model charges it), not a
        # compile failure — real naive Triton/Pallas kernels run, slowly. The
        # hard gate applies to the declared BlockSpec working set, which
        # Mosaic would genuinely refuse to allocate.
        isz = dtype_itemsize(sched.compute_dtype)
        stream = (cfg.block_m * cfg.block_k + cfg.block_k * cfg.block_n) * isz
        acc = cfg.block_m * cfg.block_n * 4
        ws = stream * max(1, cfg.num_stages) + acc
        if ws > ctx.spec.vmem_bytes:
            errors.append(
                f"INVALID VMEM working set {ws >> 20} MiB > budget "
                f"{ctx.spec.vmem_bytes >> 20} MiB in {grp.name}: shrink BLOCK_K "
                f"first, then BLOCK_N; or reduce num_stages.")
        if cfg.num_stages < 1:
            errors.append(f"INVALID num_stages={cfg.num_stages} in {grp.name}: "
                          f"must be >= 1.")
        if cfg.acc_dtype not in ("float32",):
            errors.append(
                f"INVALID acc_dtype={cfg.acc_dtype} in {grp.name}: matmul "
                f"accumulation must be float32 (bf16 acc loses ~3 digits on "
                f"long K). Fix: acc_dtype='float32'.")
        if cfg.persistent and root.op == "matmul":
            sem = tuple(cfg.dimension_semantics)
            if sem and all(s == "parallel" for s in sem):
                errors.append(
                    f"INVALID dimension_semantics={sem} in {grp.name}: a "
                    f"persistent accumulator revisits blocks; the revisiting "
                    f"dim must be 'arbitrary'.")
        if grp.impl == "pallas_naive" and root.op == "matmul" and len(root.shape) == 2:
            m, n_ = root.shape
            a_shape = g.node(root.inputs[0]).shape
            k = a_shape[0] if root.attrs.get("transpose_a") else a_shape[-1]
            if m % cfg.block_m or n_ % cfg.block_n or k % cfg.block_k:
                errors.append(
                    f"INVALID naive kernel in {grp.name}: shape ({m},{n_},{k}) not "
                    f"divisible by blocks ({cfg.block_m},{cfg.block_n},{cfg.block_k}) "
                    f"and the kernel has no boundary checks. Fix: modernize to "
                    f"BlockSpec tiling (auto-masked) or choose dividing blocks.")
    return errors


# ----------------------------------------------------------------------
def _diff_diagnostics(got: jnp.ndarray, want: jnp.ndarray,
                      rtol: float, atol: float) -> str:
    got64 = np.asarray(got, np.float64)
    want64 = np.asarray(want, np.float64)
    adiff = np.abs(got64 - want64)
    denom = np.maximum(np.abs(want64), 1e-12)
    rdiff = adiff / denom
    exceed = adiff > (atol + rtol * np.abs(want64))
    likely = []
    if got64.shape != want64.shape:
        likely.append(f"shape mismatch {got64.shape} vs {want64.shape}")
    if np.isnan(got64).any():
        likely.append("NaNs present (unstable exp/softmax? missing max-subtract?)")
    if exceed.mean() > 0.9:
        likely.append("wholesale mismatch: wrong strides / transposed loads / "
                      "wrong operand order")
    elif exceed.any():
        frac_tail = exceed.reshape(-1)[-max(1, exceed.size // 16):].mean()
        if frac_tail > 4 * exceed.mean():
            likely.append("errors concentrated at the tail: missing boundary "
                          "checks on ragged edges")
        else:
            likely.append("scattered tolerance exceedances: accumulation dtype "
                          "or reassociation too aggressive")
    return (f"max_abs_diff={adiff.max():.3e} mean_diff={adiff.mean():.3e} "
            f"max_rel_diff={rdiff.max():.3e} "
            f"exceed={int(exceed.sum())}/{exceed.size} "
            f"({100.0 * exceed.mean():.2f}%). Likely causes: "
            + ("; ".join(likely) if likely else "minor numeric drift"))


# ----------------------------------------------------------------------
def run_correctness(candidate_ci: KernelProgram,
                    ctx: ProblemContext,
                    use_pallas: bool = True,
                    session: Optional[VerifySession] = None
                    ) -> Optional[VerifyReport]:
    """Level 3 of the cascade: execute the candidate against the seeded
    oracle. Returns ``None`` when every output matches, else the failure
    report. Split out of :func:`compile_and_verify` so the cost-first
    screening path can defer it and the fallback extractor can run it
    lazily."""
    assert ctx.ci_inputs is not None and ctx.oracle_outputs is not None
    try:
        if session is not None:
            got = run_program_cached(candidate_ci, ctx.ci_inputs,
                                     ctx.ci_params or {}, session,
                                     use_pallas=use_pallas)
        else:
            got = run_program(candidate_ci, ctx.ci_inputs,
                              ctx.ci_params or {}, use_pallas=use_pallas)
    except ExecUnsupported as e:
        return VerifyReport(False, "structure",
                            f"NO KERNEL TEMPLATE: {e}. Fix: keep the group "
                            f"as impl='xla' or restructure the fusion.")
    except Exception as e:  # noqa: BLE001
        return VerifyReport(False, "correctness",
                            f"RUNTIME ERROR during execution: "
                            f"{type(e).__name__}: {e}")
    want_list = list(ctx.oracle_outputs.items())
    got_list = list(got.items())
    if len(want_list) != len(got_list):
        return VerifyReport(False, "correctness",
                            f"OUTPUT ARITY MISMATCH: candidate produces "
                            f"{len(got_list)} outputs, oracle has {len(want_list)}")
    # rewrites may rename output nodes; outputs are compared positionally
    for (key, want), (gkey, gval) in zip(want_list, got_list):
        gv = np.asarray(gval)
        wv = np.asarray(want)
        if np.isnan(gv).any():
            return VerifyReport(False, "correctness",
                                f"NaN in output {key}: "
                                + _diff_diagnostics(gval, want, ctx.rtol, ctx.atol))
        if np.isinf(gv).any() and not np.isinf(wv).any():
            return VerifyReport(False, "correctness",
                                f"Inf in output {key} where the original has none")
        if gv.shape != wv.shape:
            return VerifyReport(False, "correctness",
                                f"SHAPE MISMATCH on {key}: {gv.shape} vs {wv.shape}")
        if not np.allclose(gv, wv, rtol=ctx.rtol, atol=ctx.atol):
            return VerifyReport(
                False, "correctness",
                f"OUTPUT MISMATCH on {key} (rtol={ctx.rtol}, atol={ctx.atol}): "
                + _diff_diagnostics(gval, want, ctx.rtol, ctx.atol))
    return None


def _performance_report(cand, incumbent_time: float,
                        deferred: bool = False) -> VerifyReport:
    t = cand.total_s
    dominant = cand.dominant
    return VerifyReport(
        False, "performance",
        f"SLOWER: candidate {t*1e6:.2f}us vs incumbent "
        f"{incumbent_time*1e6:.2f}us ({incumbent_time/t:.2f}x). "
        f"Candidate achieves {cand.tflops_effective:.1f} effective TFLOPS; "
        f"dominant term: {dominant}. Suggestions: "
        f"{'reduce HBM traffic (bigger tiles, swizzle, fusion)' if 'memory' in dominant else 'raise MXU utilization (aligned >=128 tiles, bf16, pipelining)'}"
        f"; or try a different stage ordering.",
        candidate_time=t, incumbent_time=incumbent_time,
        metrics={"tflops": cand.tflops_effective},
        correctness_deferred=deferred)


def compile_and_verify(candidate_ci: KernelProgram,
                       candidate_bench: KernelProgram,
                       incumbent_time: float,
                       ctx: ProblemContext,
                       kb: KnowledgeBase,
                       cost_model: Optional[CostModel] = None,
                       min_speedup: float = MIN_SPEEDUP,
                       use_pallas: bool = True,
                       session: Optional[VerifySession] = None,
                       cost_first: bool = False) -> VerifyReport:
    """The verification cascade. ``session`` (optional) memoizes traces,
    group executions, structure checks and cost-model results across
    candidates; ``cost_first`` runs the cheap roofline check *before* the
    expensive correctness execution and defers correctness for candidates
    that cannot beat the incumbent (the report carries
    ``correctness_deferred=True``; the CoVeR fallback extractor runs it
    lazily). With both off this is the uncached reference path."""
    cost_model = cost_model or CostModel(ctx.spec)

    # -- level 1: syntax ------------------------------------------------
    try:
        candidate_ci.validate()
        candidate_bench.validate()
        if session is None or not session.trace_known_good(candidate_ci):
            in_structs = {n.name: jax.ShapeDtypeStruct(n.shape, jnp.dtype(n.dtype))
                          for n in candidate_ci.graph.inputs()}
            param_structs = {n.name: jax.ShapeDtypeStruct(n.shape, jnp.dtype(n.dtype))
                             for n in candidate_ci.graph.params()}
            jax.eval_shape(lambda i, p: run_program(candidate_ci, i, p,
                                                    use_pallas=False),
                           in_structs, param_structs)
            if session is not None:
                session.record_trace_ok(candidate_ci)
    except Exception as e:  # noqa: BLE001 — any trace failure is the diagnostic
        return VerifyReport(False, "syntax",
                            f"SYNTAX/TRACE ERROR: {type(e).__name__}: {e}")

    # -- level 2: structure ----------------------------------------------
    if session is not None:
        errors = session.structure_errors(candidate_bench, ctx, kb,
                                          _check_structure)
    else:
        errors = _check_structure(candidate_bench, ctx, kb)
    if errors:
        return VerifyReport(False, "structure", " | ".join(errors))

    # -- levels 3+4: correctness and performance --------------------------
    # The roofline result is needed either way; with ``cost_first`` it runs
    # ahead of correctness so a candidate that cannot beat the incumbent
    # skips the oracle execution entirely.
    if session is not None:
        cand = session.program_cost(cost_model, candidate_bench)
    else:
        cand = cost_model.program_cost(candidate_bench)
    slower = cand.total_s * min_speedup >= incumbent_time

    if cost_first and slower:
        if session is not None:
            session.stats.screened += 1
        return _performance_report(cand, incumbent_time, deferred=True)

    failure = run_correctness(candidate_ci, ctx, use_pallas=use_pallas,
                              session=session)
    if failure is not None:
        return failure

    if slower:
        return _performance_report(cand, incumbent_time)
    t = cand.total_s
    return VerifyReport(True, "success",
                        SUCCESS + f" ({incumbent_time/t:.2f}x, "
                        f"{cand.tflops_effective:.1f} eff-TFLOPS)",
                        candidate_time=t, incumbent_time=incumbent_time,
                        metrics={"tflops": cand.tflops_effective})


# ----------------------------------------------------------------------
def verify_candidate(candidate_ci: KernelProgram,
                     candidate_bench: KernelProgram,
                     incumbent_time: float,
                     ctx: ProblemContext,
                     kb: KnowledgeBase,
                     cost_model: Optional[CostModel] = None,
                     min_speedup: float = MIN_SPEEDUP,
                     use_pallas: bool = True,
                     session: Optional[VerifySession] = None,
                     fastpath: str = "off") -> VerifyReport:
    """Mode dispatcher over :func:`compile_and_verify`:

    * ``"off"`` (or no session) — the uncached reference cascade.
    * ``"on"`` — memoized fast path + cost-first screening. Known caveat:
      for a candidate that is *both* slower than the incumbent and
      incorrect, the trajectory observation is the performance message
      instead of the correctness one (the screen fires first). Accepted
      transforms, ``StageResult``/``TransformLog`` outcomes and fallback
      selection are unaffected (the in-tree proposers only branch on
      structure-level text, which screening never touches), but a custom
      proposer keying on correctness-failure text would see the
      performance message under screening.
    * ``"check"`` — memoized fast path with every level run, cross-checked
      bit-identical against the uncached cascade, **and** the cost-first
      screening decision the ``"on"`` mode would take is validated: a
      deferred report must hide nothing (its lazily-executed correctness
      must agree with the reference level), an undeferred one must equal
      the reference outright. Raises :class:`VerifyFastpathDivergence` on
      any mismatch.
    """
    if fastpath == "off" or session is None:
        return compile_and_verify(candidate_ci, candidate_bench,
                                  incumbent_time, ctx, kb, cost_model,
                                  min_speedup, use_pallas)
    if fastpath == "check":
        fast = compile_and_verify(candidate_ci, candidate_bench,
                                  incumbent_time, ctx, kb, cost_model,
                                  min_speedup, use_pallas, session=session)
        ref = compile_and_verify(candidate_ci, candidate_bench,
                                 incumbent_time, ctx, kb, cost_model,
                                 min_speedup, use_pallas)
        if fast != ref:
            raise VerifyFastpathDivergence(
                f"verify fast path diverged from the uncached cascade for "
                f"{ctx.name}:\n  fast: {fast}\n  ref:  {ref}")
        # cross-check the screening path too (cheap: the session is hot),
        # so "check" exercises everything "on" would actually run
        screened = compile_and_verify(candidate_ci, candidate_bench,
                                      incumbent_time, ctx, kb, cost_model,
                                      min_speedup, use_pallas,
                                      session=session, cost_first=True)
        if screened.correctness_deferred:
            failure = run_correctness(candidate_ci, ctx,
                                      use_pallas=use_pallas, session=session)
            consistent = (
                failure == ref if failure is not None
                else (ref.level == "performance" and dataclasses.replace(
                    screened, correctness_deferred=False) == ref))
            if not consistent:
                raise VerifyFastpathDivergence(
                    f"cost-first screening hid a divergent outcome for "
                    f"{ctx.name}:\n  screened: {screened}\n"
                    f"  deferred correctness: {failure}\n  ref: {ref}")
        elif screened != ref:
            raise VerifyFastpathDivergence(
                f"cost-first path diverged from the uncached cascade for "
                f"{ctx.name}:\n  screened: {screened}\n  ref:  {ref}")
        return ref
    return compile_and_verify(candidate_ci, candidate_bench, incumbent_time,
                              ctx, kb, cost_model, min_speedup, use_pallas,
                              session=session, cost_first=True)
