"""The Forge pipeline (paper §IV-A): analysis → planner → dependency-ordered
CoVeR stages with issue-driven skip logic, re-analysis between stages,
best-of-k selection, and never-degrade semantics.

Since the fleet-engine refactor the stage loop itself lives in
:class:`repro.core.stage_scheduler.StageScheduler`; ``ForgePipeline`` is the
single-job entry point that owns context preparation, best-of-k, pipeline
never-degrade, and history recording. Batch/concurrent/cached optimization
goes through :class:`repro.core.engine.OptimizationEngine`, which drives the
same scheduler.
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Mapping, Optional

import jax.numpy as jnp

from repro.core.analyzer import analyze
from repro.core.config import ForgeConfig
from repro.core.context import ProblemContext
from repro.core.history import History
from repro.core.llm import LLMClient
from repro.core.stage_scheduler import (ScheduleOutcome, StageRecord,
                                        StageScheduler, TransformLog)
from repro.core.verify_cache import VerifySession
from repro.hw.specs import TPUSpec, TPU_V5E
from repro.ir.cost import CostModel, ProgramCost
from repro.ir.interpreter import evaluate, make_inputs, make_params
from repro.ir.schedule import KernelProgram
from repro.kb.loader import KnowledgeBase, load_default

__all__ = ["ForgePipeline", "PipelineResult", "StageRecord",
           "prepare_oracle"]


def prepare_oracle(graph):
    """The trusted-harness prep for one graph: seeded inputs/weights and the
    f32 oracle outputs from the ORIGINAL graph (the candidate can never
    influence this path). Module-level so the engine's batch planner and
    ``ForgePipeline._prepare_ctx`` provably seed from the same fixed seeds —
    the invariant behind cross-job oracle-slice sharing."""
    inputs = make_inputs(graph, seed=1)
    params = make_params(graph, seed=0)
    oracle = evaluate(graph, inputs, params)
    oracle = {k: v.astype(jnp.float32) for k, v in oracle.items()}
    return inputs, params, oracle


@dataclasses.dataclass
class PipelineResult:
    name: str
    original_time: float
    optimized_time: float
    ci_program: KernelProgram
    bench_program: KernelProgram
    stage_records: List[StageRecord]
    issues_initial: List
    k_used: int = 1
    transform_log: Optional[TransformLog] = None
    cache_hit: bool = False
    clamped: bool = False            # pipeline-level never-degrade triggered
    seed_steps_applied: int = 0      # family-transfer steps that stuck

    @property
    def proposals(self) -> int:
        """Total stage-loop iterations spent (transfer's economy metric)."""
        return sum(r.iterations for r in self.stage_records)

    @property
    def speedup(self) -> float:
        return self.original_time / self.optimized_time if self.optimized_time else 1.0


class ForgePipeline:
    """Single-job optimization entry point, configured by a
    :class:`~repro.core.config.ForgeConfig`.

    The kwarg constructor is the compatibility shim for pre-facade callers:
    it folds the old kwarg sprawl into a ``ForgeConfig`` (pass ``config=``
    directly — or use the :class:`repro.core.forge.Forge` facade — in new
    code). Live resources (KB, LLM client, history) stay constructor
    arguments: they are stateful objects, not policy values."""

    def __init__(self,
                 kb: Optional[KnowledgeBase] = None,
                 spec: TPUSpec = TPU_V5E,
                 max_iterations: int = 5,
                 best_of_k: int = 1,
                 use_pallas_exec: bool = True,
                 llm: Optional[LLMClient] = None,
                 history: Optional[History] = None,
                 dump_dir: Optional[pathlib.Path] = None,
                 stages_enabled: Optional[List[str]] = None,
                 use_planner: bool = True,
                 warm_start: bool = True,
                 config: Optional[ForgeConfig] = None):
        if config is None:
            config = ForgeConfig(
                spec_name=getattr(spec, "name", str(spec)),
                max_iterations=max_iterations,
                best_of_k=best_of_k,
                use_pallas_exec=use_pallas_exec,
                use_planner=use_planner,
                warm_start=warm_start,
                stages_enabled=(None if stages_enabled is None
                                else tuple(stages_enabled)),
                use_llm=llm is not None,
                dump_dir=(str(dump_dir) if dump_dir is not None else None))
        elif llm is not None and not config.use_llm:
            # the signature must reflect that an LLM participates
            config = config.replace(use_llm=True)
        self.config = config
        self.kb = kb or load_default()
        try:
            self.spec = config.spec()
        except KeyError:
            # a custom TPUSpec object not in the generation registry is
            # honored (its name still reaches the cache key via spec_name);
            # a bare unknown spec_name is a config error, not a fallback —
            # silently optimizing for the wrong hardware poisons the cache
            if getattr(spec, "name", None) == config.spec_name:
                self.spec = spec
            else:
                raise
        self.llm = llm
        self.history = history or History()
        self.cost_model = CostModel(self.spec)

    @classmethod
    def from_config(cls, config: ForgeConfig,
                    kb: Optional[KnowledgeBase] = None,
                    llm: Optional[LLMClient] = None,
                    history: Optional[History] = None) -> "ForgePipeline":
        return cls(kb=kb, llm=llm, history=history, config=config)

    # config-derived views (kept as attributes of record for older callers)
    @property
    def T(self) -> int:
        return self.config.max_iterations

    @property
    def k(self) -> int:
        return self.config.best_of_k

    @property
    def use_pallas_exec(self) -> bool:
        return self.config.use_pallas_exec

    @property
    def use_planner(self) -> bool:
        return self.config.use_planner

    @property
    def warm_start(self) -> bool:
        return self.config.warm_start

    @property
    def stages_enabled(self) -> Optional[tuple]:
        return self.config.stages_enabled

    @property
    def dump_dir(self) -> Optional[pathlib.Path]:
        return (pathlib.Path(self.config.dump_dir)
                if self.config.dump_dir else None)

    # ------------------------------------------------------------------
    def policy_signature(self) -> str:
        """Signature of every knob that changes what the pipeline would
        produce for a given job; the engine folds it into the cache key.
        Derived from the config's fields (see
        :meth:`ForgeConfig.policy_signature`), so a newly added knob can
        never be silently omitted."""
        return self.config.policy_signature()

    def transfer_policy_signature(self) -> str:
        """Signature scoping the *transfer* (family/ladder) keys: policy
        minus search-order knobs — see
        :meth:`ForgeConfig.transfer_policy_signature`."""
        return self.config.transfer_policy_signature()

    # ------------------------------------------------------------------
    def make_verify_session(self, shared=None) -> Optional[VerifySession]:
        """A fresh per-job verification memo, or ``None`` when the fast
        path is off. The engine creates one per job and shares it between
        the replay attempt and the full-optimization fallback. ``shared``
        is the engine-owned cross-job :class:`SharedVerifyCache` the
        session reads through / writes back; under ``verify_fastpath=
        "check"`` every shared hit is additionally byte-validated against
        a fresh local execution before it is adopted."""
        if self.config.verify_fastpath == "off":
            return None
        return VerifySession(
            shared=shared,
            check_shared=(self.config.verify_fastpath == "check"))

    def make_scheduler(self, priors: Optional[Mapping[str, int]] = None,
                       on_stage_complete=None,
                       session: Optional[VerifySession] = None
                       ) -> StageScheduler:
        """Build a StageScheduler with this pipeline's configuration. The
        engine calls this too, so every policy knob lives in one place."""
        if priors is None:
            priors = (self.history.snapshot_priors(self.config.prior_policy)
                      if self.warm_start else {})
        return StageScheduler(self.kb, self.cost_model,
                              max_iterations=self.T, llm=self.llm,
                              dump_dir=self.dump_dir,
                              use_pallas_exec=self.use_pallas_exec,
                              stages_enabled=self.stages_enabled,
                              use_planner=self.use_planner,
                              priors=priors,
                              on_stage_complete=(on_stage_complete
                                                 or self.on_stage_complete),
                              verify_fastpath=self.config.verify_fastpath,
                              session=session,
                              prior_policy=self.config.prior_policy,
                              cost_rank_proposals=(
                                  self.config.cost_rank_proposals))

    # observer hook threaded into every scheduler this pipeline builds;
    # the Forge facade sets it, old-style callers leave it None
    on_stage_complete = None

    def stage_hook(self, on_stage=None):
        """Combine the pipeline-global ``on_stage_complete`` hook with a
        per-call ``on_stage`` callback (the engine threads one through for
        per-job event fan-out — e.g. the Forge service's SSE streams). The
        global hook always fires first; either side may be None."""
        base = self.on_stage_complete
        if on_stage is None:
            return base
        if base is None:
            return on_stage

        def both(name, record):
            base(name, record)
            on_stage(name, record)
        return both

    # ------------------------------------------------------------------
    def _prepare_ctx(self, name: str, ci_program: KernelProgram,
                     tags, target_dtype: str, rtol: float, atol: float,
                     meta: Dict,
                     session: Optional[VerifySession] = None
                     ) -> ProblemContext:
        """Build the trusted harness context: seeded inputs/weights and the
        oracle outputs computed from the ORIGINAL graph in f32 (the candidate
        can never influence this path). With a ``session`` the prep is
        memoized per exact graph — a replay fallback re-prepares the same
        context the replay attempt already computed."""
        g = ci_program.graph
        if session is not None:
            inputs, params, oracle = session.oracle_prep(g, prepare_oracle)
        else:
            inputs, params, oracle = prepare_oracle(g)
        return ProblemContext(name=name, target_dtype=target_dtype,
                              rtol=rtol, atol=atol, spec=self.spec,
                              tags=tuple(tags), ci_inputs=inputs,
                              ci_params=params, oracle_outputs=oracle,
                              meta=dict(meta))

    # ------------------------------------------------------------------
    def optimize(self, name: str,
                 ci_program: KernelProgram,
                 bench_program: KernelProgram,
                 tags=(), target_dtype: str = "bfloat16",
                 rtol: float = 1e-2, atol: float = 1e-5,
                 meta: Optional[Dict] = None,
                 priors: Optional[Mapping[str, int]] = None,
                 seed_log: Optional[TransformLog] = None,
                 session: Optional[VerifySession] = None,
                 on_stage=None) -> PipelineResult:
        """Optimize a single kernel job. This is the thin single-job wrapper;
        fleet submission (batching, caching, concurrency) lives in
        ``OptimizationEngine.run_batch``, which funnels back into the same
        stage scheduler this method drives. ``seed_log`` is a family
        neighbor's transform sequence (engine transfer path): the scheduler
        warm-starts from it, verifying each step on this job's real shapes,
        and falls back to the full search from wherever it diverges.
        ``session`` is the job's verification memo (the engine shares one
        between replay and this fallback); a fresh one is created when the
        fast path is on and none was supplied. ``on_stage`` is an optional
        per-call stage observer fired *in addition to* the pipeline-global
        hook (see :meth:`stage_hook`)."""
        if session is None:
            session = self.make_verify_session()
        ctx = self._prepare_ctx(name, ci_program, tags, target_dtype,
                                rtol, atol, meta or {}, session=session)
        original_cost = self.cost_model.program_cost(bench_program)
        scheduler = self.make_scheduler(priors, session=session,
                                        on_stage_complete=self.stage_hook(on_stage))

        # apply a transfer seed once, up front: apply_seed is deterministic
        # (same programs, same ctx), so re-locating and re-verifying the
        # identical prefix on every best-of-k pass would be pure waste
        prefix = None
        if seed_log is not None and len(seed_log):
            prefix = scheduler.apply_seed(seed_log, ci_program.copy(),
                                          bench_program.copy(), ctx)

        best: Optional[PipelineResult] = None
        for pass_idx in range(max(1, self.k)):
            result = self._single_pass(scheduler, name, ci_program.copy(),
                                       bench_program.copy(), ctx,
                                       original_cost, pass_idx,
                                       prefix=prefix)
            if best is None or result.optimized_time < best.optimized_time:
                best = result
        best.k_used = max(1, self.k)
        return best

    # ------------------------------------------------------------------
    def _single_pass(self, scheduler: StageScheduler, name: str,
                     ci_prog: KernelProgram, bench_prog: KernelProgram,
                     ctx: ProblemContext, original_cost: ProgramCost,
                     pass_idx: int,
                     prefix=None) -> PipelineResult:
        """One stage-loop pass. ``prefix`` is a pre-applied transfer seed
        (``StageScheduler.apply_seed`` output): the pass continues the full
        search from the seeded programs and the seed's records/log are
        stitched onto the outcome. A partially-applicable seed can never
        produce a worse result than cold — remaining issues still get their
        full proposal search, and every seeded step was verified faster."""
        if prefix is not None:
            seed_ci, seed_bench, seed_records, seed_applied, applied = prefix
            out: ScheduleOutcome = scheduler.run(
                name, seed_ci.copy(), seed_bench.copy(), ctx,
                pass_idx=pass_idx, history=self.history)
            # issues_initial reports the PRE-seed inventory (ci_prog /
            # bench_prog are the unseeded copies), so warm and cold runs of
            # the same kernel describe the same starting point
            out = ScheduleOutcome(
                out.ci_program, out.bench_program,
                list(seed_records) + out.records,
                list(analyze(bench_prog, ctx)),
                TransformLog(list(seed_applied.steps)
                             + out.transform_log.steps),
                seed_steps_applied=applied)
        else:
            out = scheduler.run(name, ci_prog, bench_prog, ctx,
                                pass_idx=pass_idx, history=self.history)
        return self._finalize(name, out, original_cost)

    # ------------------------------------------------------------------
    def _finalize(self, name: str, out: ScheduleOutcome,
                  original_cost: ProgramCost,
                  cache_hit: bool = False) -> PipelineResult:
        final_time = self.cost_model.program_time(out.bench_program)
        # pipeline-level never-degrade (paper §IV-B-e)
        if final_time > original_cost.total_s:
            return PipelineResult(name, original_cost.total_s,
                                  original_cost.total_s, out.ci_program,
                                  out.bench_program, out.records,
                                  out.issues_initial,
                                  transform_log=out.transform_log,
                                  cache_hit=cache_hit, clamped=True,
                                  seed_steps_applied=out.seed_steps_applied)
        return PipelineResult(name, original_cost.total_s, final_time,
                              out.ci_program, out.bench_program, out.records,
                              out.issues_initial,
                              transform_log=out.transform_log,
                              cache_hit=cache_hit,
                              seed_steps_applied=out.seed_steps_applied)
