"""The Forge pipeline (paper §IV-A): analysis → planner → dependency-ordered
CoVeR stages with issue-driven skip logic, re-analysis between stages,
best-of-k selection, and never-degrade semantics.
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core.analyzer import analyze
from repro.core.context import ProblemContext
from repro.core.cover import CoVeRAgent, StageResult
from repro.core.history import History
from repro.core.llm import LLMClient
from repro.core.planner import plan
from repro.core.proposers import make_proposer
from repro.core.verify import compile_and_verify
from repro.hw.specs import TPUSpec, TPU_V5E
from repro.ir.cost import CostModel, ProgramCost
from repro.ir.interpreter import evaluate, make_inputs, make_params
from repro.ir.schedule import KernelProgram
from repro.kb.loader import KnowledgeBase, load_default


@dataclasses.dataclass
class StageRecord:
    stage: str
    improved: bool
    iterations: int
    speedup: Optional[float]
    description: str
    fallback_used: bool


@dataclasses.dataclass
class PipelineResult:
    name: str
    original_time: float
    optimized_time: float
    ci_program: KernelProgram
    bench_program: KernelProgram
    stage_records: List[StageRecord]
    issues_initial: List
    k_used: int = 1

    @property
    def speedup(self) -> float:
        return self.original_time / self.optimized_time if self.optimized_time else 1.0


class ForgePipeline:
    def __init__(self,
                 kb: Optional[KnowledgeBase] = None,
                 spec: TPUSpec = TPU_V5E,
                 max_iterations: int = 5,
                 best_of_k: int = 1,
                 use_pallas_exec: bool = True,
                 llm: Optional[LLMClient] = None,
                 history: Optional[History] = None,
                 dump_dir: Optional[pathlib.Path] = None,
                 stages_enabled: Optional[List[str]] = None,
                 use_planner: bool = True):
        self.kb = kb or load_default()
        self.spec = spec
        self.T = max_iterations
        self.k = best_of_k
        self.use_pallas_exec = use_pallas_exec
        self.llm = llm
        self.history = history or History()
        self.dump_dir = dump_dir
        self.stages_enabled = stages_enabled          # ablation hook
        self.use_planner = use_planner                # ablation hook
        self.cost_model = CostModel(spec)

    # ------------------------------------------------------------------
    def _prepare_ctx(self, name: str, ci_program: KernelProgram,
                     tags, target_dtype: str, rtol: float, atol: float,
                     meta: Dict) -> ProblemContext:
        """Build the trusted harness context: seeded inputs/weights and the
        oracle outputs computed from the ORIGINAL graph in f32 (the candidate
        can never influence this path)."""
        g = ci_program.graph
        inputs = make_inputs(g, seed=1)
        params = make_params(g, seed=0)
        oracle = evaluate(g, inputs, params)
        oracle = {k: v.astype(jnp.float32) for k, v in oracle.items()}
        return ProblemContext(name=name, target_dtype=target_dtype,
                              rtol=rtol, atol=atol, spec=self.spec,
                              tags=tuple(tags), ci_inputs=inputs,
                              ci_params=params, oracle_outputs=oracle,
                              meta=dict(meta))

    # ------------------------------------------------------------------
    def optimize(self, name: str,
                 ci_program: KernelProgram,
                 bench_program: KernelProgram,
                 tags=(), target_dtype: str = "bfloat16",
                 rtol: float = 1e-2, atol: float = 1e-5,
                 meta: Optional[Dict] = None) -> PipelineResult:
        ctx = self._prepare_ctx(name, ci_program, tags, target_dtype,
                                rtol, atol, meta or {})
        original_cost = self.cost_model.program_cost(bench_program)

        best: Optional[PipelineResult] = None
        for pass_idx in range(max(1, self.k)):
            result = self._single_pass(name, ci_program.copy(),
                                       bench_program.copy(), ctx,
                                       original_cost, pass_idx)
            if best is None or result.optimized_time < best.optimized_time:
                best = result
        best.k_used = max(1, self.k)
        return best

    # ------------------------------------------------------------------
    def _single_pass(self, name: str, ci_prog: KernelProgram,
                     bench_prog: KernelProgram, ctx: ProblemContext,
                     original_cost: ProgramCost, pass_idx: int) -> PipelineResult:
        records: List[StageRecord] = []
        issues = analyze(bench_prog, ctx)
        issues_initial = list(issues)
        order = plan(issues, llm=self.llm) if self.use_planner else [
            s for s in ("algorithmic", "discovery", "dtype_fix", "fusion",
                        "memory_access", "block_pointers", "persistent_kernel",
                        "gpu_specific", "autotuning")]
        if self.stages_enabled is not None:
            order = [s for s in order if s in self.stages_enabled]

        executed = set()
        while order:
            stage = order.pop(0)
            if stage in executed:
                continue
            executed.add(stage)
            stage_issues = [i for i in issues if i.stage == stage]
            if not stage_issues:
                continue  # skip logic: no issues -> no stage execution
            proposer = make_proposer(stage, self.kb, ctx)
            agent = CoVeRAgent(stage, proposer, self.kb,
                               max_iterations=self.T,
                               dump_dir=self.dump_dir,
                               use_pallas_exec=self.use_pallas_exec)
            incumbent = self.cost_model.program_time(bench_prog)
            res: StageResult = agent.run(ci_prog, bench_prog, stage_issues, ctx,
                                         incumbent, self.cost_model,
                                         start_offset=pass_idx)
            speedup = res.report.speedup if (res.report and res.improved) else None
            records.append(StageRecord(stage, res.improved, res.iterations,
                                       speedup,
                                       res.accepted.description if res.accepted else "",
                                       res.fallback_used))
            self.history.record(name, stage,
                                res.accepted.pattern_id if res.accepted else "",
                                res.improved, speedup, res.iterations)
            if res.improved:
                ci_prog, bench_prog = res.ci_program, res.bench_program
                # re-analysis (paper §IV-A-c): refresh the issue list; newly
                # surfaced issues can activate not-yet-run stages
                issues = analyze(bench_prog, ctx)
                pos = {s: i for i, s in enumerate(order)}
                for i in issues:
                    if i.stage not in executed and i.stage not in pos:
                        new_order = plan(issues, llm=self.llm)
                        order = [s for s in new_order if s not in executed]
                        if self.stages_enabled is not None:
                            order = [s for s in order
                                     if s in self.stages_enabled]
                        break
            else:
                issues = analyze(bench_prog, ctx)

        final_time = self.cost_model.program_time(bench_prog)
        # pipeline-level never-degrade (paper §IV-B-e)
        if final_time > original_cost.total_s:
            return PipelineResult(name, original_cost.total_s,
                                  original_cost.total_s, ci_prog, bench_prog,
                                  records, issues_initial)
        return PipelineResult(name, original_cost.total_s, final_time,
                              ci_prog, bench_prog, records, issues_initial)
