"""Reusable stage loop (extracted from ``ForgePipeline._single_pass``).

The :class:`StageScheduler` owns the analyze → plan → CoVeR-per-stage →
re-analyze loop and, as it goes, records an explicit serializable
:class:`TransformLog` — the sequence of accepted (stage, pattern_id,
description) transforms. The log is what makes fleet-level result caching
possible: a structurally identical kernel (same fingerprint) can *replay*
the verified winning sequence — one verification per accepted transform —
instead of re-running the full nine-stage proposal search.

History-driven warm starts: when success-count priors are supplied, each
stage's proposer is wrapped so historically productive patterns are tried
first (stable reorder: ties keep the proposer's deterministic order).
"""

from __future__ import annotations

import dataclasses
import re
import weakref
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.analyzer import analyze
from repro.core.context import ProblemContext
from repro.core.cover import CoVeRAgent, StageResult
from repro.core.llm import LLMClient
from repro.core.planner import plan
from repro.core.proposers import BaseProposer, Candidate, make_proposer
from repro.core.stages import DEFAULT_REGISTRY
from repro.core.verify import verify_candidate
from repro.core.verify_cache import VerifySession
from repro.ir.cost import CostModel
from repro.ir.fingerprint import cached_canonical_name_map
from repro.ir.graph import Graph
from repro.ir.schedule import KernelProgram
from repro.kb.loader import KnowledgeBase

# per-graph memo of the compiled description translator: replay re-
# canonicalizes every proposed candidate's description against the same
# graph, so rebuilding the name map + regex list per call was the hot spot.
# Graphs are copy-on-write throughout the pipeline (transforms never mutate
# in place), so keying on the object is sound; WeakKey keeps discarded
# candidates from pinning their translators.
_TRANSLATOR_CACHE: "weakref.WeakKeyDictionary[Graph, List[tuple]]" = \
    weakref.WeakKeyDictionary()


def _description_translator(graph: Graph) -> List[tuple]:
    pats = _TRANSLATOR_CACHE.get(graph)
    if pats is None:
        nm = cached_canonical_name_map(graph)
        # group names follow the g_<node> convention; map them alongside nodes
        full = dict(nm)
        full.update({f"g_{k}": f"g_{v}" for k, v in nm.items()})
        pats = [(re.compile(rf"(?<![A-Za-z0-9_]){re.escape(name)}"
                            rf"(?![A-Za-z0-9_])"), full[name])
                for name in sorted(full, key=len, reverse=True)]
        _TRANSLATOR_CACHE[graph] = pats
    return pats


def canonical_description(description: str, graph: Graph) -> str:
    """Rewrite node/group names embedded in a candidate description (e.g.
    ``fuse:mm+reduction``, ``mem:pack-b:g_mm``) to canonical topo-position
    names, so transform logs match across structurally identical programs
    whose only difference is labeling."""
    for pattern, repl in _description_translator(graph):
        description = pattern.sub(repl, description)
    return description


@dataclasses.dataclass
class StageRecord:
    stage: str
    improved: bool
    iterations: int
    speedup: Optional[float]
    description: str
    fallback_used: bool


@dataclasses.dataclass
class TransformStep:
    """One accepted transform: enough to re-locate the candidate on replay.
    ``canonical_description`` is the description with node names rewritten to
    topo positions — the rename-invariant match key for structural twins."""

    stage: str
    pattern_id: str
    description: str
    canonical_description: str = ""

    def to_dict(self) -> Dict[str, str]:
        return {"stage": self.stage, "pattern_id": self.pattern_id,
                "description": self.description,
                "canonical_description": self.canonical_description}

    @classmethod
    def from_dict(cls, d: Dict[str, str]) -> "TransformStep":
        return cls(stage=d["stage"], pattern_id=d.get("pattern_id", ""),
                   description=d.get("description", ""),
                   canonical_description=d.get("canonical_description", ""))


@dataclasses.dataclass
class TransformLog:
    steps: List[TransformStep] = dataclasses.field(default_factory=list)

    def append(self, stage: str, pattern_id: str, description: str,
               canonical_description: str = ""):
        self.steps.append(TransformStep(stage, pattern_id, description,
                                        canonical_description))

    def to_list(self) -> List[Dict[str, str]]:
        return [s.to_dict() for s in self.steps]

    @classmethod
    def from_list(cls, items: List[Dict[str, str]]) -> "TransformLog":
        return cls(steps=[TransformStep.from_dict(d) for d in items])

    def __len__(self) -> int:
        return len(self.steps)

    def __iter__(self):
        return iter(self.steps)


class WarmStartProposer(BaseProposer):
    """Reorders a proposer's candidates by historical priors.

    With empty priors this is a transparent pass-through, so cold runs are
    bit-identical to the un-warmed pipeline. Two ranking policies:

    ``"counts"`` — the original stable sort by flat success count (ties keep
    the proposer's deterministic order): bit-exact legacy behavior.
    ``"mined"``  — total-order ranking by (mined prior score desc, roofline
    cost estimate asc, pattern_id asc, proposal index asc). The ``estimator``
    callable (``(candidate, program) -> (total_s, hbm_bytes) | None``) fills
    each candidate's ``cost_estimate`` before ranking, so the downstream
    agent can early-stop once every residual candidate is dominated.
    """

    def __init__(self, inner: BaseProposer, priors: Mapping[str, int],
                 policy: str = "counts", estimator=None):
        self.inner = inner
        self.stage = inner.stage
        self.kb = inner.kb
        self.ctx = inner.ctx
        self.priors = priors
        self.policy = policy
        self.estimator = estimator

    def _prior_score(self, pattern_id: str) -> float:
        score = getattr(self.priors, "score", None)
        if score is not None:
            return score(self.stage, pattern_id)
        return float(self.priors.get(pattern_id, 0))

    def candidates(self, program, issues, trajectory):
        cands = list(self.inner.candidates(program, issues, trajectory))
        if self.policy != "mined":
            # legacy stable sort; empty priors = bit-exact passthrough
            if self.priors:
                cands.sort(key=lambda c: -self.priors.get(c.pattern_id, 0))
            return iter(cands)
        if self.estimator is not None:
            for c in cands:
                if c.cost_estimate is None:
                    c.cost_estimate = self.estimator(c, program)
        elif not self.priors:
            return iter(cands)  # nothing to rank by

        def rank(pair):
            idx, c = pair
            est = (c.cost_estimate if c.cost_estimate is not None
                   else (float("inf"), float("inf")))
            return (-self._prior_score(c.pattern_id), est[0], est[1],
                    c.pattern_id, idx)

        return iter(c for _, c in sorted(enumerate(cands), key=rank))


@dataclasses.dataclass
class ScheduleOutcome:
    ci_program: KernelProgram
    bench_program: KernelProgram
    records: List[StageRecord]
    issues_initial: List
    transform_log: TransformLog
    seed_steps_applied: int = 0      # transferred neighbor steps that stuck

    @property
    def proposals(self) -> int:
        """Total stage-loop work: one per CoVeR iteration (seeded steps
        count 1 verification each). The transfer acceptance metric."""
        return sum(r.iterations for r in self.records)


class StageScheduler:
    """Dependency-ordered CoVeR stage executor with replay support."""

    def __init__(self, kb: KnowledgeBase, cost_model: CostModel,
                 max_iterations: int = 5,
                 llm: Optional[LLMClient] = None,
                 dump_dir=None,
                 use_pallas_exec: bool = True,
                 stages_enabled: Optional[List[str]] = None,
                 use_planner: bool = True,
                 priors: Optional[Mapping[str, int]] = None,
                 on_stage_complete=None,
                 verify_fastpath: str = "off",
                 session: Optional[VerifySession] = None,
                 prior_policy: str = "counts",
                 cost_rank_proposals: bool = False):
        self.kb = kb
        self.cost_model = cost_model
        self.T = max_iterations
        self.llm = llm
        self.dump_dir = dump_dir
        self.use_pallas_exec = use_pallas_exec
        self.stages_enabled = stages_enabled
        self.use_planner = use_planner
        # PriorSnapshot carries mined stats alongside the counts view; keep
        # it intact rather than flattening to the counts dict
        self.priors = (priors if isinstance(priors, Mapping) and priors
                       else dict(priors or {}))
        self.prior_policy = prior_policy
        self.cost_rank_proposals = cost_rank_proposals
        # observer hook: called with (job_name, StageRecord) after every
        # stage execution (search, replay, and seeded-transfer steps alike)
        self.on_stage_complete = on_stage_complete
        # verification fast path: schedulers are built per job, so a fresh
        # session here is correctly job-scoped when the caller supplies none
        self.verify_fastpath = verify_fastpath
        self.session = session or (VerifySession()
                                   if verify_fastpath != "off" else None)

    def _emit(self, ctx: ProblemContext, record: StageRecord):
        if self.on_stage_complete is not None:
            self.on_stage_complete(ctx.name, record)

    def _program_time(self, program: KernelProgram) -> float:
        """Incumbent time, memoized through the verify session (the same
        bench program is re-costed once per stage and once per verify)."""
        if self.session is not None:
            return self.session.program_time(self.cost_model, program)
        return self.cost_model.program_time(program)

    def _cost_estimate(self, cand: Candidate, program: KernelProgram):
        """Roofline (total_s, hbm_bytes) of the candidate applied to
        ``program``; None when the transform fails (ranked last — the agent
        still pops it eventually and records the error observation)."""
        try:
            transformed = cand.transform(program)
        except Exception:  # noqa: BLE001 — estimate failure is not an error
            return None
        if self.session is not None:
            cost = self.session.program_cost(self.cost_model, transformed)
            return (cost.total_s, cost.hbm_bytes)
        return self.cost_model.program_rank_estimate(transformed)

    # ------------------------------------------------------------------
    def _make_proposer(self, stage: str, ctx: ProblemContext) -> BaseProposer:
        proposer = make_proposer(stage, self.kb, ctx)
        if self.prior_policy == "mined" and (self.priors
                                             or self.cost_rank_proposals):
            return WarmStartProposer(
                proposer, self.priors, policy="mined",
                estimator=(self._cost_estimate if self.cost_rank_proposals
                           else None))
        if self.priors:
            return WarmStartProposer(proposer, self.priors)
        return proposer

    def _plan(self, issues) -> List[str]:
        if self.use_planner:
            order = plan(issues, llm=self.llm)
        else:
            # planner-off ablation: the registry's full deterministic order
            order = DEFAULT_REGISTRY.default_order()
        if self.stages_enabled is not None:
            order = [s for s in order if s in self.stages_enabled]
        return order

    # ------------------------------------------------------------------
    def run(self, name: str, ci_prog: KernelProgram,
            bench_prog: KernelProgram, ctx: ProblemContext,
            pass_idx: int = 0, history=None) -> ScheduleOutcome:
        """The full analyze → plan → CoVeR → re-analyze loop (one pass)."""
        records: List[StageRecord] = []
        log = TransformLog()
        issues = analyze(bench_prog, ctx)
        issues_initial = list(issues)
        order = self._plan(issues)

        executed = set()
        while order:
            stage = order.pop(0)
            if stage in executed:
                continue
            executed.add(stage)
            stage_issues = [i for i in issues if i.stage == stage]
            if not stage_issues:
                continue  # skip logic: no issues -> no stage execution
            proposer = self._make_proposer(stage, ctx)
            agent = CoVeRAgent(stage, proposer, self.kb,
                               max_iterations=self.T,
                               dump_dir=self.dump_dir,
                               use_pallas_exec=self.use_pallas_exec,
                               session=self.session,
                               fastpath=self.verify_fastpath)
            incumbent = self._program_time(bench_prog)
            res: StageResult = agent.run(ci_prog, bench_prog, stage_issues,
                                         ctx, incumbent, self.cost_model,
                                         start_offset=pass_idx)
            speedup = res.report.speedup if (res.report and res.improved) else None
            records.append(StageRecord(stage, res.improved, res.iterations,
                                       speedup,
                                       res.accepted.description if res.accepted else "",
                                       res.fallback_used))
            self._emit(ctx, records[-1])
            if history is not None:
                history.record(name, stage,
                               res.accepted.pattern_id if res.accepted else "",
                               res.improved, speedup, res.iterations,
                               tried=res.tried_pattern_ids)
            if res.improved:
                desc = res.accepted.description if res.accepted else ""
                # canonicalize against the pre-transform graph — that's what
                # the candidate descriptions were generated from
                canon = canonical_description(desc, bench_prog.graph)
                ci_prog, bench_prog = res.ci_program, res.bench_program
                log.append(stage, res.accepted.pattern_id if res.accepted else "",
                           desc, canon)
                # re-analysis (paper §IV-A-c): refresh the issue list; a
                # re-plan is only worth its cost when a genuinely *new*
                # stage surfaced (neither executed nor already scheduled)
                issues = analyze(bench_prog, ctx)
                scheduled = executed | set(order)
                if any(i.stage not in scheduled for i in issues):
                    order = [s for s in self._plan(issues)
                             if s not in executed]
            else:
                issues = analyze(bench_prog, ctx)

        return ScheduleOutcome(ci_prog, bench_prog, records, issues_initial,
                               log)

    # ------------------------------------------------------------------
    def _locate_step(self, step: TransformStep,
                     bench_prog: KernelProgram,
                     ctx: ProblemContext) -> Optional[Candidate]:
        """Re-locate a logged transform among the current proposals: exact
        description first, then canonical (rename-invariant) description,
        then pattern id — the match key ladder shared by exact replay and
        family transfer."""
        issues = analyze(bench_prog, ctx)
        stage_issues = [i for i in issues if i.stage == step.stage]
        proposer = make_proposer(step.stage, self.kb, ctx)
        cands = list(proposer.candidates(bench_prog, stage_issues, []))
        cand = next((c for c in cands
                     if c.description == step.description), None)
        if cand is None and step.canonical_description:
            # renamed structural twin: match on canonical descriptions
            cand = next(
                (c for c in cands
                 if canonical_description(c.description, bench_prog.graph)
                 == step.canonical_description), None)
        if cand is None and step.pattern_id:
            cand = next((c for c in cands
                         if c.pattern_id == step.pattern_id), None)
        return cand

    def _apply_step(self, step: TransformStep, ci_prog: KernelProgram,
                    bench_prog: KernelProgram, ctx: ProblemContext
                    ) -> Optional[Tuple[KernelProgram, KernelProgram,
                                        StageRecord, Candidate]]:
        """Apply one logged step with full verification; None on divergence."""
        cand = self._locate_step(step, bench_prog, ctx)
        if cand is None:
            return None
        incumbent = self._program_time(bench_prog)
        try:
            new_ci = cand.transform(ci_prog)
            new_bench = cand.transform(bench_prog)
        except Exception:  # noqa: BLE001 — divergence -> fall back
            return None
        report = verify_candidate(new_ci, new_bench, incumbent, ctx,
                                  self.kb, self.cost_model,
                                  use_pallas=self.use_pallas_exec,
                                  session=self.session,
                                  fastpath=self.verify_fastpath)
        if not report.ok:
            return None
        record = StageRecord(step.stage, True, 1, report.speedup,
                             cand.description, False)
        return new_ci, new_bench, record, cand

    # ------------------------------------------------------------------
    def replay(self, log: TransformLog, ci_prog: KernelProgram,
               bench_prog: KernelProgram, ctx: ProblemContext
               ) -> Optional[Tuple[KernelProgram, KernelProgram,
                                   List[StageRecord]]]:
        """Re-apply a verified transform sequence on a (structurally
        identical) program: one candidate lookup + one verification per step
        instead of the full CoVeR search. Returns None on any divergence —
        the caller falls back to full optimization, so replay is always
        correctness-safe."""
        records: List[StageRecord] = []
        for step in log:
            out = self._apply_step(step, ci_prog, bench_prog, ctx)
            if out is None:
                return None
            ci_prog, bench_prog, record, _ = out
            records.append(record)
            self._emit(ctx, record)
        return ci_prog, bench_prog, records

    # ------------------------------------------------------------------
    def apply_seed(self, seed: TransformLog, ci_prog: KernelProgram,
                   bench_prog: KernelProgram, ctx: ProblemContext
                   ) -> Tuple[KernelProgram, KernelProgram,
                              List[StageRecord], TransformLog, int]:
        """Speculatively apply a *family neighbor's* transform log (same
        builder, different dims). Unlike :meth:`replay`, divergence is not
        failure: each step is verified on this job's real shapes and the
        first step that no longer locates, transforms, or verifies simply
        ends the seeded prefix — the caller continues the full search from
        there. Verified steps are appended to a fresh log with descriptions
        re-canonicalized against *this* job's graph."""
        records: List[StageRecord] = []
        log = TransformLog()
        applied = 0
        for step in seed:
            out = self._apply_step(step, ci_prog, bench_prog, ctx)
            if out is None:
                break
            new_ci, new_bench, record, cand = out
            # canonicalize against the pre-transform graph — that's what the
            # candidate description was generated from (mirrors run())
            canon = canonical_description(cand.description, bench_prog.graph)
            records.append(record)
            self._emit(ctx, record)
            log.append(step.stage, cand.pattern_id, cand.description, canon)
            ci_prog, bench_prog = new_ci, new_bench
            applied += 1
        return ci_prog, bench_prog, records, log, applied

