"""Core: the paper's contribution — the multi-stage CoVeR optimization
pipeline with knowledge-base-driven proposers and 4-level verification."""

from repro.core.analyzer import analyze
from repro.core.context import ProblemContext
from repro.core.cover import CoVeRAgent, Trajectory
from repro.core.issues import Issue, ISSUE_TO_STAGE, register_issue_type
from repro.core.pipeline import ForgePipeline, PipelineResult
from repro.core.planner import plan, DEFAULT_ORDER, HARD_DEPS
from repro.core.verify import compile_and_verify, VerifyReport, SUCCESS

__all__ = [
    "analyze", "ProblemContext", "CoVeRAgent", "Trajectory", "Issue",
    "ISSUE_TO_STAGE", "register_issue_type", "ForgePipeline",
    "PipelineResult", "plan", "DEFAULT_ORDER", "HARD_DEPS",
    "compile_and_verify", "VerifyReport", "SUCCESS",
]
