"""Core: the paper's contribution — the multi-stage CoVeR optimization
pipeline with knowledge-base-driven proposers and 4-level verification,
plus the fleet-scale engine (batching, caching, concurrency) layered on
top of it."""

from repro.core.analyzer import analyze
from repro.core.config import (EXECUTION_BACKENDS, PRIOR_POLICIES,
                               VERIFY_FASTPATH_MODES, ForgeConfig)
from repro.core.history import History, PatternStats, PriorSnapshot
from repro.core.job_codec import (decode_job, decode_pipeline_result,
                                  decode_program, encode_job,
                                  encode_pipeline_result, encode_program)
from repro.core.context import ProblemContext
from repro.core.cover import CoVeRAgent, Trajectory
from repro.core.engine import (EngineResult, EngineStats, KernelJob,
                               OptimizationEngine, VerifyStats)
from repro.core.faults import (FaultPlan, InjectedCrash,
                               deterministic_backoff)
from repro.core.journal import Journal, JournalCorruption, JournalError
from repro.core.forge import Forge, OptimizationReport
from repro.core.job_codec import (SUPPORTED_WIRE_VERSIONS, WIRE_VERSION,
                                  WireDecodeError, WireVersionError)
from repro.core.observers import (CallbackObserver, FanOutObserver,
                                  ForgeObserver, JobEvent, StageEvent,
                                  TransferEvent, as_observer)
from repro.core.result_store import ResultCache, ResultStore
from repro.core.issues import Issue, ISSUE_TO_STAGE, register_issue_type
from repro.core.pipeline import ForgePipeline, PipelineResult, StageRecord
from repro.core.planner import plan, DEFAULT_ORDER, HARD_DEPS
from repro.core.stage_scheduler import (StageScheduler, TransformLog,
                                        TransformStep)
from repro.core.stages import (DEFAULT_REGISTRY, StageRegistry,
                               StageRegistryError, StageSpec, register_stage)
from repro.core.verify import (compile_and_verify, verify_candidate,
                               VerifyReport, SUCCESS)
from repro.core.verify_cache import (SharedVerifyCache,
                                     VerifyFastpathDivergence, VerifySession,
                                     run_program_cached)

__all__ = [
    "analyze", "ProblemContext", "CoVeRAgent", "Trajectory", "Issue",
    "ISSUE_TO_STAGE", "register_issue_type", "ForgePipeline",
    "PipelineResult", "StageRecord", "plan", "DEFAULT_ORDER", "HARD_DEPS",
    "compile_and_verify", "verify_candidate", "VerifyReport", "SUCCESS",
    "VerifySession", "SharedVerifyCache", "VerifyFastpathDivergence",
    "run_program_cached", "VERIFY_FASTPATH_MODES",
    "OptimizationEngine", "KernelJob", "EngineResult", "EngineStats",
    "VerifyStats",
    "ResultCache", "ResultStore", "StageScheduler", "TransformLog",
    "TransformStep",
    "Forge", "ForgeConfig", "ForgeObserver", "OptimizationReport",
    "StageEvent", "JobEvent", "TransferEvent", "CallbackObserver",
    "FanOutObserver", "as_observer",
    "WIRE_VERSION", "SUPPORTED_WIRE_VERSIONS", "WireDecodeError",
    "WireVersionError",
    "EXECUTION_BACKENDS", "PRIOR_POLICIES",
    "FaultPlan", "InjectedCrash", "deterministic_backoff",
    "Journal", "JournalError", "JournalCorruption",
    "History", "PatternStats", "PriorSnapshot",
    "encode_job", "decode_job", "encode_program", "decode_program",
    "encode_pipeline_result", "decode_pipeline_result",
    "StageSpec", "StageRegistry", "StageRegistryError", "DEFAULT_REGISTRY",
    "register_stage",
]
