"""Socket transport for the distributed worker fleet.

Frames are length-prefixed JSON over TCP: an 8-byte big-endian length
header followed by a UTF-8 JSON document.  Every frame is passed
through the job-codec value codec (:func:`repro.core.job_codec
.encode_value` / :func:`decode_value`), so tuples — cache keys,
ladders, dims, seed pairs — survive the socket boundary with the same
bit-exact fidelity the process backend gets from pickle.  That is what
lets the remote backend reuse the tagged ``("keys", ...)`` /
``("job", ...)`` / ``("stage", ...)`` worker protocol from
``core/engine.py`` unchanged.

Handshake (worker connects to the coordinator):

1. worker → ``hello``   {protocol_version, wire_version, pid, host}
2. coord  → ``config``  {config, kb (b64 pickle), policy_signature,
                         kb_content_hash, heartbeat_s, ...}
            or ``reject`` {reason} when versions mismatch
3. worker → ``ready``   {policy_signature, kb_content_hash}
            or ``abort`` {reason} when its rebuilt pipeline disagrees
4. coord  → drops the connection on a ``ready`` mismatch, else the
            worker joins the fleet and starts pulling tasks.

Both sides re-derive the policy signature and KB content hash from the
shipped config independently and compare — a stale worker binary (old
wire format, old policy fields) can never silently join and corrupt a
fleet; it is rejected with a typed reason at step 2 or 4.

After the handshake the coordinator sends ``task`` / ``ping`` /
``shutdown`` frames; the worker answers with ``event`` / ``pong``.
``task`` and ``event`` frames carry a run id so events from an aborted
run can never be folded into a later one.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Any, Optional, Tuple

from repro.core import job_codec
from repro.core.job_codec import WIRE_VERSION

__all__ = [
    "PROTOCOL_VERSION",
    "WIRE_VERSION",
    "MAX_FRAME_BYTES",
    "RemoteProtocolError",
    "HandshakeRejected",
    "send_frame",
    "recv_frame",
    "parse_address",
    "format_address",
    "hello_frame",
    "validate_hello",
]

#: Version of the fleet *transport* protocol (framing + handshake +
#: task/event message shapes).  Distinct from ``WIRE_VERSION``, which
#: versions the job-codec payload envelopes carried inside frames.
PROTOCOL_VERSION = 1

#: Hard per-frame ceiling — a corrupt length header must not make the
#: receiver try to allocate gigabytes.
MAX_FRAME_BYTES = 256 * 1024 * 1024

_HEADER = struct.Struct(">Q")


class RemoteProtocolError(RuntimeError):
    """A peer violated the fleet framing/handshake protocol."""


class HandshakeRejected(RemoteProtocolError):
    """The coordinator rejected this worker's handshake."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def send_frame(sock: socket.socket, message: Any) -> None:
    """Serialize *message* (tuple-fidelity preserved) and send one frame."""
    data = json.dumps(job_codec.encode_value(message),
                      separators=(",", ":")).encode("utf-8")
    if len(data) > MAX_FRAME_BYTES:
        raise RemoteProtocolError(
            f"frame of {len(data)} bytes exceeds MAX_FRAME_BYTES")
    sock.sendall(_HEADER.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly *n* bytes; None on clean EOF before the first byte."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise RemoteProtocolError(
                f"connection dropped mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> Optional[Any]:
    """Receive one frame; ``None`` on orderly connection close."""
    header = _recv_exact(sock, _HEADER.size)
    if header is None:
        return None
    (length,) = _HEADER.unpack(header)
    if length > MAX_FRAME_BYTES:
        raise RemoteProtocolError(
            f"peer announced a {length}-byte frame (max {MAX_FRAME_BYTES})")
    body = _recv_exact(sock, length)
    if body is None:
        raise RemoteProtocolError("connection dropped before frame body")
    try:
        return job_codec.decode_value(json.loads(body.decode("utf-8")))
    except (ValueError, UnicodeDecodeError) as exc:
        raise RemoteProtocolError(f"undecodable frame: {exc}") from exc


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)`` (bare ``":port"`` binds all)."""
    host, sep, port = address.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"fleet address must be 'host:port', got {address!r}")
    return (host or "0.0.0.0", int(port))


def format_address(host: str, port: int) -> str:
    return f"{host}:{port}"


def hello_frame(*, pid: int, host: str,
                protocol_version: int = PROTOCOL_VERSION,
                wire_version: int = WIRE_VERSION) -> dict:
    return {
        "type": "hello",
        "protocol_version": protocol_version,
        "wire_version": wire_version,
        "pid": pid,
        "host": host,
    }


def validate_hello(hello: Any) -> Optional[str]:
    """Return a rejection reason for a worker ``hello``, or None if OK."""
    if not isinstance(hello, dict) or hello.get("type") != "hello":
        return "handshake must open with a 'hello' frame"
    proto = hello.get("protocol_version")
    if proto != PROTOCOL_VERSION:
        return (f"protocol_version mismatch: worker speaks {proto!r}, "
                f"coordinator speaks {PROTOCOL_VERSION}")
    wire = hello.get("wire_version")
    if wire != WIRE_VERSION:
        return (f"wire_version mismatch: worker speaks {wire!r}, "
                f"coordinator speaks {WIRE_VERSION}")
    return None
