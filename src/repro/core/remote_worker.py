"""``forge-worker``: one fleet worker process.

Connects to a :class:`repro.core.fleet.FleetCoordinator`, completes the
versioned handshake (hello → config → ready), rebuilds a private
:class:`~repro.core.pipeline.ForgePipeline` from the shipped ForgeConfig
plus pickled knowledge base, and serves tagged tasks until a
``shutdown`` frame or connection loss. The task loop is the process
backend's ``_process_worker_main`` with a socket in place of
multiprocessing queues: ``("keys", idx, job_wire)`` computes cache keys
worker-side, ``("job", idx, ...)`` optimizes, stage records stream back
as ``("stage", ...)`` events, and each finished job returns the same
``{"result", "entry", "outcome", "history"}`` payload — so the parent
engine folds remote results through the exact code path it uses for
process workers.

Workers are stateless between tasks (a fresh History per job, no store,
no stats): a worker lost mid-job can be replaced by re-dispatching the
job to any surviving worker with no state to reconcile.

Usage::

    forge-worker --connect HOST:PORT [--reconnect N] [--fault-plan JSON]

Exit codes: 0 orderly shutdown/drain, 2 handshake rejected by the
coordinator, 3 worker-side policy/KB cross-check failed, 4 connection
lost (retried with capped deterministic backoff when ``--reconnect N``
is given — deliberate drain/rejection never retries), 17/18 injected
faults (kill / dropped-frame sever).
"""

from __future__ import annotations

import argparse
import base64
import os
import pickle
import queue as queue_mod
import socket
import sys
import threading
import time
import traceback
from typing import Optional

from repro.core import job_codec, remote
from repro.core.faults import FaultPlan, deterministic_backoff

__all__ = ["run_worker", "main"]

#: Fault-injection exit code (``--die-after`` / kill_worker_after_jobs),
#: distinct from every legitimate exit so tests can assert the death was
#: the injected one.
DIE_EXIT_CODE = 17

#: Fault-injection exit code for ``drop_frame_after``: the worker severed
#: its socket instead of sending an event frame, then exited.
DROP_EXIT_CODE = 18


def run_worker(connect: str, die_after: Optional[int] = None,
               hello_protocol_version: Optional[int] = None,
               hello_wire_version: Optional[int] = None,
               fault_plan: Optional[FaultPlan] = None) -> int:
    """Run the worker loop against coordinator *connect* ("host:port").

    ``die_after`` is the legacy fault-injection knob, kept for the fleet
    tests: the worker calls ``os._exit(17)`` upon receiving job task
    number ``die_after + 1`` (keys tasks don't count) — i.e.
    ``--die-after 0`` dies on its first job, after dispatch but before
    any partial work. ``fault_plan`` generalizes it
    (:class:`repro.core.faults.FaultPlan`: kill-after-K-jobs, sever the
    socket instead of sending event frame N). The ``hello_*_version``
    overrides exist solely to exercise handshake rejection.
    """
    if fault_plan is None and die_after is not None:
        fault_plan = FaultPlan(kill_worker_after_jobs=die_after)
    # heavy imports deferred past arg parsing so ``forge-worker --help``
    # stays instant and import errors surface after the CLI contract
    from repro.core.config import ForgeConfig
    from repro.core.engine import compute_job_keys, execute_job
    from repro.core.history import History
    from repro.core.pipeline import ForgePipeline
    from repro.core.verify_cache import SharedVerifyCache

    host, port = remote.parse_address(connect)
    try:
        sock = socket.create_connection((host, port), timeout=30.0)
    except OSError as exc:
        print(f"forge-worker: cannot reach coordinator at {connect}: {exc}",
              file=sys.stderr)
        return 4
    try:
        sock.settimeout(60.0)  # handshake window
        hello_kwargs = {"pid": os.getpid(), "host": socket.gethostname()}
        if hello_protocol_version is not None:
            hello_kwargs["protocol_version"] = hello_protocol_version
        if hello_wire_version is not None:
            hello_kwargs["wire_version"] = hello_wire_version
        remote.send_frame(sock, remote.hello_frame(**hello_kwargs))
        msg = remote.recv_frame(sock)
        if msg is None:
            print("forge-worker: coordinator closed during handshake",
                  file=sys.stderr)
            return 4
        if isinstance(msg, dict) and msg.get("type") == "reject":
            print(f"forge-worker: handshake rejected: {msg.get('reason')}",
                  file=sys.stderr)
            return 2
        if not isinstance(msg, dict) or msg.get("type") != "config":
            print(f"forge-worker: expected config frame, got "
                  f"{type(msg).__name__}", file=sys.stderr)
            return 4

        config = ForgeConfig.from_dict(msg["config"])
        kb = (pickle.loads(base64.b64decode(msg["kb"]))
              if msg.get("kb") else None)
        pipeline = ForgePipeline.from_config(config, kb=kb)
        # independent cross-check: this build must derive the same policy
        # signature and KB content hash the coordinator derived — a stale
        # worker binary (old policy fields, old hashing) aborts here
        # instead of silently joining and corrupting the fleet
        signature = pipeline.policy_signature()
        kb_hash = pipeline.kb.content_hash()
        if (signature != msg.get("policy_signature")
                or kb_hash != msg.get("kb_content_hash")):
            remote.send_frame(sock, {
                "type": "abort",
                "reason": (f"policy/KB cross-check failed: worker derived "
                           f"({signature!r}, {kb_hash!r}), coordinator sent "
                           f"({msg.get('policy_signature')!r}, "
                           f"{msg.get('kb_content_hash')!r})")})
            print("forge-worker: policy/KB cross-check failed; this worker "
                  "build disagrees with the coordinator", file=sys.stderr)
            return 3
        remote.send_frame(sock, {"type": "ready",
                                 "policy_signature": signature,
                                 "kb_content_hash": kb_hash,
                                 "pid": os.getpid()})
        sock.settimeout(None)
    except (OSError, remote.RemoteProtocolError) as exc:
        print(f"forge-worker: handshake failed: {exc}", file=sys.stderr)
        sock.close()
        return 4

    send_lock = threading.Lock()

    def send(message: dict) -> None:
        with send_lock:
            remote.send_frame(sock, message)

    # reader thread: answers pings inline, funnels tasks to the main loop,
    # turns shutdown/EOF into the None sentinel
    tasks: "queue_mod.Queue" = queue_mod.Queue()

    def reader() -> None:
        while True:
            try:
                message = remote.recv_frame(sock)
            except (OSError, remote.RemoteProtocolError):
                message = None
            if message is None or not isinstance(message, dict):
                tasks.put(None)
                return
            kind = message.get("type")
            if kind == "ping":
                try:
                    send({"type": "pong"})
                except (OSError, remote.RemoteProtocolError):
                    tasks.put(None)
                    return
            elif kind == "task":
                tasks.put(message)
            elif kind == "shutdown":
                tasks.put(None)
                return

    threading.Thread(target=reader, daemon=True,
                     name="forge-worker-reader").start()

    shared = None
    if (config.shared_verify_cache_bytes > 0
            and config.verify_fastpath != "off"):
        shared = SharedVerifyCache(config.shared_verify_cache_bytes)
    jobs_seen = 0
    while True:
        message = tasks.get()
        if message is None:
            return 0
        run_id = message.get("run")
        task = message["task"]
        kind, idx = task[0], task[1]

        def emit(event, _run=run_id):
            if fault_plan is not None and fault_plan.take_event_frame():
                # drop-frame injection: sever the socket instead of
                # sending this event — the coordinator sees EOF, marks
                # the worker lost, and must re-dispatch its task
                try:
                    sock.close()
                except OSError:
                    pass
                os._exit(DROP_EXIT_CODE)
            send({"type": "event", "run": _run, "event": event})

        try:
            if kind == "keys":
                job = job_codec.decode_job(task[2])
                emit(("keys", idx, compute_job_keys(pipeline, job)))
                continue
            if fault_plan is not None \
                    and fault_plan.worker_should_die(jobs_seen):
                # fault injection: die after dispatch, before any work —
                # the coordinator must detect the loss and re-dispatch
                os._exit(DIE_EXIT_CODE)
            jobs_seen += 1
            _, _, job_wire, exact_key, family_key, priors_wire, entry, \
                seed_pairs, warm_wire = task
            job = job_codec.decode_job(job_wire)
            priors = job_codec.decode_priors(priors_wire)
            if warm_wire is not None and shared is not None:
                for key, value in job_codec.decode_verify_slice(warm_wire):
                    shared.put(key, value)
            # fresh per-task history, streamed-back stage events, and the
            # process-worker result payload — see _process_worker_main
            pipeline.history = History()
            pipeline.on_stage_complete = (
                lambda name, rec, _idx=idx, _emit=emit: _emit(
                    ("stage", _idx, name,
                     job_codec.encode_stage_record(rec))))
            result, outcome = execute_job(pipeline, job, entry, seed_pairs,
                                          exact_key, priors, shared=shared)
            emit(("result", idx, {
                "result": job_codec.encode_pipeline_result(result),
                "entry": outcome.pop("entry"),
                "outcome": outcome,
                "history": list(pipeline.history.records),
            }))
        except (OSError, remote.RemoteProtocolError):
            return 4  # connection gone; nothing left to report to
        except Exception:  # noqa: BLE001 — marshal the traceback up
            try:
                emit(("error", idx, traceback.format_exc()))
            except (OSError, remote.RemoteProtocolError):
                return 4


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="forge-worker",
        description="Fleet worker for the Xe-Forge remote execution "
                    "backend: connects to a coordinator, rebuilds the "
                    "pipeline from the handshake, and serves optimization "
                    "tasks until drained.")
    parser.add_argument("--connect", required=True, metavar="HOST:PORT",
                        help="coordinator fleet address")
    parser.add_argument("--die-after", type=int, default=None,
                        metavar="N",
                        help="fault injection for fleet tests: exit(17) "
                             "upon receiving job task N+1 (keys tasks "
                             "don't count)")
    parser.add_argument("--fault-plan", default=None, metavar="JSON",
                        help="deterministic fault injection: a "
                             "repro.core.faults.FaultPlan in to_json() "
                             "form (generalizes --die-after; chaos gate "
                             "and fleet tests only)")
    parser.add_argument("--reconnect", type=int, default=0, metavar="N",
                        help="on connection loss (exit code 4), retry the "
                             "coordinator up to N times with capped "
                             "deterministic backoff; deliberate drain "
                             "(exit 0) and handshake rejection never "
                             "retry")
    # handshake-rejection test hooks
    parser.add_argument("--hello-protocol-version", type=int, default=None,
                        help=argparse.SUPPRESS)
    parser.add_argument("--hello-wire-version", type=int, default=None,
                        help=argparse.SUPPRESS)
    return parser


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    fault_plan = (FaultPlan.from_json(args.fault_plan)
                  if args.fault_plan else None)
    attempt = 0
    try:
        while True:
            rc = run_worker(
                args.connect, die_after=args.die_after,
                hello_protocol_version=args.hello_protocol_version,
                hello_wire_version=args.hello_wire_version,
                fault_plan=fault_plan)
            # retry ONLY transport loss (4): a drain (0) is deliberate,
            # and a rejection (2) / cross-check failure (3) would just
            # repeat — this worker build can never join that fleet
            if rc != 4 or attempt >= max(0, args.reconnect):
                return rc
            delay = deterministic_backoff(
                f"reconnect:{args.connect}:{os.getpid()}", attempt,
                base_s=0.2, cap_s=5.0)
            print(f"forge-worker: connection lost; reconnect "
                  f"{attempt + 1}/{args.reconnect} in {delay:.2f}s",
                  file=sys.stderr)
            time.sleep(delay)
            attempt += 1
    except KeyboardInterrupt:
        return 130


if __name__ == "__main__":
    sys.exit(main())
