"""Picklable/JSON-safe wire codec for kernel jobs and their results.

The process-pool execution backend (``ForgeConfig.execution_backend =
"process"``) has to move three kinds of values across an OS process boundary:

* **down** — a :class:`~repro.core.engine.KernelJob` (two
  :class:`~repro.ir.schedule.KernelProgram` values plus tolerances/tags/meta)
  and the store entries that seed replay/transfer;
* **up** — a :class:`~repro.core.pipeline.PipelineResult` (optimized
  programs, stage records, issue inventory, transform log) plus observer
  events streaming back through the results queue.

``ForgeConfig`` already pickles (PR 3); this module is the remaining half of
the ROADMAP's process-pool follow-up: an explicit wire form for the program
values. Everything encodes to plain JSON types (dict/list/str/num/bool/None),
so the wire form survives *any* transport — ``pickle`` across a ``spawn``
boundary, a JSON file, a results queue — and decoding is **bit-exact**: the
decoded program's structural fingerprint (:mod:`repro.ir.fingerprint`) is
identical to the original's, which is what lets a worker process compute the
same cache keys and replay the same logs the parent would.

Tuples inside node attrs (``perm=(1, 0)``, ``axes=(1,)``) are preserved
through JSON via a ``{"__tuple__": [...]}`` tag — fingerprints canonicalize
tuples and lists identically, but the interpreter/analyzer see the decoded
attrs directly, so the codec must hand back *exactly* what the builder wrote.
Graphs are re-assembled node-for-node (no shape re-inference), so decode
needs no jax evaluation and cannot drift from the encoded form.
"""

from __future__ import annotations

import base64
import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.issues import Issue
from repro.core.pipeline import PipelineResult
from repro.core.stage_scheduler import StageRecord, TransformLog
from repro.ir.graph import Graph, Node
from repro.ir.schedule import KernelProgram, Schedule

__all__ = [
    "WireDecodeError",
    "WireVersionError",
    "WIRE_VERSION",
    "SUPPORTED_WIRE_VERSIONS",
    "encode_value", "decode_value",
    "encode_graph", "decode_graph",
    "encode_program", "decode_program",
    "encode_job", "decode_job",
    "encode_pipeline_result", "decode_pipeline_result",
    "encode_array", "decode_array",
    "encode_verify_slice", "decode_verify_slice",
    "encode_priors", "decode_priors",
    "job_fingerprint_from_wire",
]

#: Version of the payload envelope format. Bump on any change to the
#: wire shapes below; decoders refuse envelopes from other versions with
#: a typed :class:`WireVersionError` so a stale peer (old worker binary,
#: old client) can never silently mis-decode a payload.
WIRE_VERSION = 1

#: Every envelope version this build can decode. Currently just the
#: native one; append here when a decoder grows back-compat branches.
SUPPORTED_WIRE_VERSIONS = (1,)

_TUPLE_TAG = "__tuple__"


class WireDecodeError(ValueError):
    """A wire payload could not be decoded: missing keys, wrong types, bad
    base64, truncated array bytes, malformed nested structures.

    The codec is the Forge *service's* input-validation boundary — payloads
    arrive off an HTTP socket, not from our own encoder — so every decoder
    converts the bare ``KeyError``/``TypeError``/``ValueError`` zoo a hostile
    payload can trigger into this single typed error (the HTTP layer maps it
    to a 400). Trusted in-process callers (the process-pool backend) are
    unaffected: well-formed wire forms decode exactly as before."""


class WireVersionError(WireDecodeError):
    """A wire envelope declares a ``wire_version`` this build does not
    speak. Subclasses :class:`WireDecodeError` so every existing handler
    (the HTTP 400 mapping, the ``_wire_guard`` pass-through) already
    treats it as a malformed payload; the distinct type lets the fleet
    handshake and tests tell a version skew apart from corruption."""

    def __init__(self, kind: str, version):
        super().__init__(
            f"{kind} wire payload declares wire_version {version!r}; this "
            f"build speaks {sorted(SUPPORTED_WIRE_VERSIONS)}")
        self.kind = kind
        self.version = version


def _check_wire_version(wire: Dict[str, Any], kind: str) -> None:
    """Reject envelopes from an unknown wire version.

    A missing version field is accepted as the current version: nested
    payloads (graphs, stage records) never carried one, and hand-built
    dicts in tests/drivers predate the field. Legacy envelopes spelled
    it ``version``; both spellings are honored.
    """
    version = wire.get("wire_version", wire.get("version"))
    if version is not None and version not in SUPPORTED_WIRE_VERSIONS:
        raise WireVersionError(kind, version)


def _wire_guard(kind: str):
    """Decorator: any structural failure inside a decoder becomes one typed
    :class:`WireDecodeError` naming the payload kind. A nested decoder's
    WireDecodeError passes through untouched so the innermost (most
    specific) context wins."""
    def wrap(fn):
        def guarded(wire, *args, **kwargs):
            try:
                return fn(wire, *args, **kwargs)
            except WireDecodeError:
                raise
            except (KeyError, TypeError, ValueError, AttributeError,
                    IndexError) as exc:
                raise WireDecodeError(
                    f"malformed {kind} wire payload: "
                    f"{type(exc).__name__}: {exc}") from exc
        guarded.__name__ = fn.__name__
        guarded.__doc__ = fn.__doc__
        return guarded
    return wrap


def _expect_mapping(wire, kind: str) -> Dict[str, Any]:
    if not isinstance(wire, dict):
        raise WireDecodeError(
            f"malformed {kind} wire payload: expected a JSON object, "
            f"got {type(wire).__name__}")
    return wire


def _enc_value(value):
    """JSON-safe attr encoding that round-trips tuples exactly."""
    if isinstance(value, tuple):
        return {_TUPLE_TAG: [_enc_value(v) for v in value]}
    if isinstance(value, list):
        return [_enc_value(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _enc_value(v) for k, v in value.items()}
    return value


def _dec_value(value):
    if isinstance(value, dict):
        if set(value) == {_TUPLE_TAG}:
            return tuple(_dec_value(v) for v in value[_TUPLE_TAG])
        return {k: _dec_value(v) for k, v in value.items()}
    if isinstance(value, list):
        return [_dec_value(v) for v in value]
    return value


# Public names for the tuple-fidelity value codec. The fleet transport
# (``repro.core.remote``) runs every socket frame through these, so keys,
# ladders, dims and seed pairs cross TCP with the same exactness the
# process backend gets from pickle.
encode_value = _enc_value
decode_value = _dec_value


# ----------------------------------------------------------------------
# Graph / KernelProgram
# ----------------------------------------------------------------------

def encode_graph(graph: Graph) -> Dict[str, Any]:
    """Wire form of a graph: nodes in insertion order (the toposort prefers
    insertion order, so preserving it keeps canonical renaming — and with it
    the fingerprint — bit-identical)."""
    return {
        "name": graph.name,
        "nodes": [
            {"name": n.name, "op": n.op, "inputs": list(n.inputs),
             "attrs": _enc_value(n.attrs), "shape": list(n.shape),
             "dtype": str(n.dtype)}
            for n in graph.nodes.values()
        ],
        "outputs": list(graph.outputs),
    }


@_wire_guard("graph")
def decode_graph(wire: Dict[str, Any]) -> Graph:
    """Re-assemble node-for-node: shapes/dtypes come off the wire verbatim
    (no re-inference), so decoding needs no jax evaluation."""
    _expect_mapping(wire, "graph")
    g = Graph(str(wire.get("name", "graph")))
    for d in wire["nodes"]:
        _expect_mapping(d, "graph node")
        g.nodes[d["name"]] = Node(
            name=d["name"], op=d["op"], inputs=list(d["inputs"]),
            attrs=_dec_value(d["attrs"]), shape=tuple(d["shape"]),
            dtype=str(d["dtype"]))
    g.outputs = list(wire.get("outputs", []))
    g.reseed_counter()
    return g


def encode_program(program: KernelProgram) -> Dict[str, Any]:
    return {
        "wire_version": WIRE_VERSION,
        "name": program.name,
        "graph": encode_graph(program.graph),
        "schedule": program.schedule.to_dict(),
        "original_flops": program.original_flops,
        "meta": _enc_value(program.meta),
    }


@_wire_guard("program")
def decode_program(wire: Dict[str, Any]) -> KernelProgram:
    _expect_mapping(wire, "program")
    _check_wire_version(wire, "program")
    return KernelProgram(
        name=wire["name"],
        graph=decode_graph(wire["graph"]),
        schedule=Schedule.from_dict(wire["schedule"]),
        original_flops=float(wire.get("original_flops", 0.0)),
        meta=_dec_value(wire.get("meta", {})))


# ----------------------------------------------------------------------
# KernelJob
# ----------------------------------------------------------------------

def encode_job(job) -> Dict[str, Any]:
    """Wire form of a :class:`~repro.core.engine.KernelJob` (taken by duck
    type to avoid an import cycle with ``core.engine``)."""
    return {
        "wire_version": WIRE_VERSION,
        "name": job.name,
        "ci_program": encode_program(job.ci_program),
        "bench_program": encode_program(job.bench_program),
        "tags": [str(t) for t in job.tags],
        "target_dtype": job.target_dtype,
        "rtol": job.rtol,
        "atol": job.atol,
        "meta": _enc_value(job.meta),
    }


@_wire_guard("job")
def decode_job(wire: Dict[str, Any]):
    from repro.core.engine import KernelJob

    _expect_mapping(wire, "job")
    _check_wire_version(wire, "job")
    return KernelJob(
        name=str(wire["name"]),
        ci_program=decode_program(wire["ci_program"]),
        bench_program=decode_program(wire["bench_program"]),
        tags=tuple(wire.get("tags", ())),
        target_dtype=wire.get("target_dtype", "bfloat16"),
        rtol=float(wire.get("rtol", 1e-2)),
        atol=float(wire.get("atol", 1e-5)),
        meta=_dec_value(wire.get("meta", {})))


# ----------------------------------------------------------------------
# Arrays + shared-verify warm slices (parent -> worker)
# ----------------------------------------------------------------------

def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype string, including jax-only names (``bfloat16``)
    that plain numpy rejects without the ml_dtypes registration jax ships."""
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        return np.dtype(getattr(jnp, name))


def encode_array(arr) -> Dict[str, Any]:
    """Bit-exact JSON-safe wire form of an array: dtype + shape + base64 of
    the contiguous raw bytes. Bit-exactness matters — warm-slice entries
    are content-addressed, and in check mode they are byte-compared against
    a fresh local execution."""
    a = np.ascontiguousarray(np.asarray(arr))
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


@_wire_guard("array")
def decode_array(wire: Dict[str, Any]):
    import jax.numpy as jnp
    _expect_mapping(wire, "array")
    # validate=True: reject junk characters instead of silently dropping
    # them (the default) and decoding a truncated buffer
    a = np.frombuffer(base64.b64decode(wire["data"], validate=True),
                      dtype=_np_dtype(wire["dtype"]))
    return jnp.asarray(a.reshape(tuple(wire["shape"])))


def encode_verify_slice(items: List[tuple]) -> Dict[str, Any]:
    """Wire form of a list of ``SharedVerifyCache`` entries — ``("group",
    fp) -> [(position, array), ...]`` and ``("oracle", fp) -> (inputs_list,
    params_list, oracle_list)`` — the planner's warm slice shipped with a
    process-backend job dispatch."""
    entries = []
    for (kind, fp), value in items:
        if kind == "group":
            payload = [[int(p), encode_array(a)] for p, a in value]
        else:  # "oracle": three positional array lists
            payload = [[encode_array(a) for a in part] for part in value]
        entries.append({"kind": kind, "fp": fp, "value": payload})
    return {"wire_version": WIRE_VERSION, "entries": entries}


@_wire_guard("verify slice")
def decode_verify_slice(wire: Dict[str, Any]) -> List[tuple]:
    _expect_mapping(wire, "verify slice")
    _check_wire_version(wire, "verify slice")
    items = []
    for e in wire.get("entries", []):
        _expect_mapping(e, "verify slice entry")
        if e["kind"] == "group":
            value = [(int(p), decode_array(a)) for p, a in e["value"]]
        else:
            value = tuple([decode_array(a) for a in part]
                          for part in e["value"])
        items.append(((e["kind"], e["fp"]), value))
    return items


def encode_priors(priors) -> Dict[str, Any]:
    """Wire form of a batch-frozen prior: either a legacy flat counts dict
    or a :class:`repro.core.history.PriorSnapshot` (mined statistics ride
    along so worker-side candidate ordering matches the parent's)."""
    to_dict = getattr(priors, "to_dict", None)
    if to_dict is not None:
        return {"wire_version": WIRE_VERSION, "snapshot": to_dict()}
    return {"wire_version": WIRE_VERSION, "counts": dict(priors or {})}


@_wire_guard("priors")
def decode_priors(wire: Dict[str, Any]):
    _expect_mapping(wire, "priors")
    _check_wire_version(wire, "priors")
    if "snapshot" in wire:
        from repro.core.history import PriorSnapshot
        return PriorSnapshot.from_dict(wire["snapshot"])
    return dict(wire.get("counts", {}))


def job_fingerprint_from_wire(wire: Dict[str, Any], spec_name: str,
                              policy: str = "") -> str:
    """Decode a job wire form and return its exact structural fingerprint.
    Used by the pickle-across-spawn self-check: a worker process computing
    this must agree bit-for-bit with the parent's in-memory fingerprint, or
    cache keys would diverge across the process boundary."""
    return decode_job(wire).fingerprint(spec_name, policy)


# ----------------------------------------------------------------------
# PipelineResult (worker -> parent)
# ----------------------------------------------------------------------

def encode_stage_record(record: StageRecord) -> Dict[str, Any]:
    return dataclasses.asdict(record)


@_wire_guard("stage record")
def decode_stage_record(wire: Dict[str, Any]) -> StageRecord:
    _expect_mapping(wire, "stage record")
    return StageRecord(**wire)


def _encode_issue(issue: Issue) -> Dict[str, Any]:
    return {"type": issue.type, "severity": issue.severity,
            "description": issue.description,
            "suggested_fix": issue.suggested_fix,
            "estimated_speedup": issue.estimated_speedup,
            "node": issue.node, "proposal": _enc_value(issue.proposal)}


def _decode_issue(wire: Dict[str, Any]) -> Issue:
    return Issue(type=wire["type"], severity=wire["severity"],
                 description=wire.get("description", ""),
                 suggested_fix=wire.get("suggested_fix", ""),
                 estimated_speedup=wire.get("estimated_speedup", ""),
                 node=wire.get("node"),
                 proposal=_dec_value(wire.get("proposal", {})))


def encode_pipeline_result(result: PipelineResult) -> Dict[str, Any]:
    return {
        "wire_version": WIRE_VERSION,
        "name": result.name,
        "original_time": result.original_time,
        "optimized_time": result.optimized_time,
        "ci_program": encode_program(result.ci_program),
        "bench_program": encode_program(result.bench_program),
        "stage_records": [encode_stage_record(r) for r in result.stage_records],
        "issues_initial": [_encode_issue(i) for i in result.issues_initial],
        "k_used": result.k_used,
        "transform_log": (result.transform_log.to_list()
                          if result.transform_log is not None else None),
        "cache_hit": result.cache_hit,
        "clamped": result.clamped,
        "seed_steps_applied": result.seed_steps_applied,
    }


@_wire_guard("pipeline result")
def decode_pipeline_result(wire: Dict[str, Any]) -> PipelineResult:
    _expect_mapping(wire, "pipeline result")
    _check_wire_version(wire, "pipeline result")
    log = wire.get("transform_log")
    return PipelineResult(
        name=wire["name"],
        original_time=float(wire["original_time"]),
        optimized_time=float(wire["optimized_time"]),
        ci_program=decode_program(wire["ci_program"]),
        bench_program=decode_program(wire["bench_program"]),
        stage_records=[decode_stage_record(r)
                       for r in wire.get("stage_records", [])],
        issues_initial=[_decode_issue(i)
                        for i in wire.get("issues_initial", [])],
        k_used=int(wire.get("k_used", 1)),
        transform_log=(TransformLog.from_list(log) if log is not None
                       else None),
        cache_hit=bool(wire.get("cache_hit", False)),
        clamped=bool(wire.get("clamped", False)),
        seed_steps_applied=int(wire.get("seed_steps_applied", 0)))
