"""Append-only crash-safe journal: the durability substrate for the
hosted service and the fleet coordinator.

Both PR-8's :class:`~repro.serve.service.ForgeService` and PR-9's
:class:`~repro.core.fleet.FleetCoordinator` kept their queues purely in
memory: a process restart forgot every queued job and every in-flight
wave. This module is the write-ahead log both now commit to *before*
acknowledging work (the service journals a submit before its 202; the
coordinator journals a dispatch before the task frame goes out), so a
restart replays the journal and resumes instead of forgetting.

On-disk format (all integers big-endian)::

    header:  8s  magic   b"XEFORGEJ"
             I   version (1)
             I   reserved (0)
    record:  I   payload length in bytes
             I   CRC-32 of the payload bytes
             Nx  payload — UTF-8 JSON of ``job_codec.encode_value(rec)``

Payloads go through the same tuple-tagging value codec the process/fleet
wire uses (:mod:`repro.core.job_codec`), so fleet task tuples and job
wire forms round-trip the journal with the exact fidelity every other
transport in the stack guarantees.

Crash-tolerance contract, exercised by ``tests/test_journal.py`` and the
chaos CI gate:

* **Torn final record tolerated.** A crash mid-append (power loss, the
  ``FaultPlan.torn_write_record`` injection) leaves a partial record at
  the tail. Load detects it (short header, short payload, or a
  CRC-mismatched *final* record), truncates the file back to the last
  clean record, and continues — losing only the append that never
  committed, which by protocol was never acknowledged to anyone.
* **Corruption elsewhere is typed, never silent.** A CRC mismatch on any
  record *with committed records after it* cannot be a torn tail — it is
  bit rot or tampering, and load raises :class:`JournalCorruption`
  rather than guessing which half of history to keep.
* **fsync-on-commit.** Every ``append`` flushes and ``os.fsync``\\ s by
  default (``sync=False`` opts a caller out where the record is merely
  an optimization, e.g. completion records that only save replay work).
* **Atomic compaction.** :meth:`Journal.compact` rewrites the journal as
  header + the given records via temp-file + fsync + ``os.replace`` —
  a crash at any point leaves either the old journal or the new one,
  never a hybrid.
"""

from __future__ import annotations

import json
import os
import struct
import threading
import zlib
from typing import Any, Dict, List, Optional

from repro.core import job_codec
from repro.core.faults import FaultPlan, InjectedCrash

__all__ = ["Journal", "JournalError", "JournalCorruption",
           "JOURNAL_MAGIC", "JOURNAL_VERSION"]

JOURNAL_MAGIC = b"XEFORGEJ"
JOURNAL_VERSION = 1

_HEADER = struct.Struct(">8sII")    # magic, version, reserved
_REC = struct.Struct(">II")         # payload length, payload crc32


class JournalError(RuntimeError):
    """The file is not a journal this build can read: bad magic, an
    unsupported version, or an unreadable path."""


class JournalCorruption(JournalError):
    """A committed (non-final) record failed its CRC — bit rot or
    tampering, not a torn tail, so load refuses rather than truncates."""


def _encode_record(record: Any) -> bytes:
    payload = json.dumps(job_codec.encode_value(record), sort_keys=True,
                         separators=(",", ":")).encode("utf-8")
    return _REC.pack(len(payload), zlib.crc32(payload)) + payload


class Journal:
    """One open journal file. ``__init__`` scans and recovers (truncating
    a torn tail); :attr:`records` holds everything recovered, in commit
    order, and :meth:`append` extends both the file and the list.

    Thread-safe: appends can arrive from HTTP handler threads (service
    submits) while the dispatcher appends terminal records.
    """

    def __init__(self, path: str, fault_plan: Optional[FaultPlan] = None,
                 sync: bool = True):
        self.path = str(path)
        self.sync = sync
        self.fault_plan = fault_plan
        self._lock = threading.Lock()
        self._readonly = False
        self.records: List[Any] = []
        self.recovered = 0          # records present when the file opened
        self.appended = 0
        self.truncated_tail = False
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        fresh = (not os.path.exists(self.path)
                 or os.path.getsize(self.path) < _HEADER.size)
        if not fresh:
            self._fh = open(self.path, "r+b")
            self._scan()
        else:
            # missing, empty, or torn *header* (a crash during creation —
            # nothing was ever committed to it): start clean
            self.truncated_tail = os.path.exists(self.path) and \
                os.path.getsize(self.path) > 0
            self._fh = open(self.path, "w+b")
            self._fh.write(_HEADER.pack(JOURNAL_MAGIC, JOURNAL_VERSION, 0))
            self._commit()
        self.recovered = len(self.records)

    # -- load ----------------------------------------------------------
    def _scan(self) -> None:
        fh = self._fh
        header = fh.read(_HEADER.size)
        magic, version, _ = _HEADER.unpack(header)
        if magic != JOURNAL_MAGIC:
            fh.close()
            raise JournalError(
                f"{self.path}: not a forge journal (bad magic {magic!r})")
        if version != JOURNAL_VERSION:
            fh.close()
            raise JournalError(
                f"{self.path}: journal version {version} unsupported "
                f"(this build reads {JOURNAL_VERSION})")
        clean_end = _HEADER.size
        pending: Optional[Any] = None   # last record, held back one step:
        # a CRC failure is only "torn tail" if nothing committed after it
        pending_bad = False
        while True:
            rec_header = fh.read(_REC.size)
            if not rec_header:
                break
            if len(rec_header) < _REC.size:
                self.truncated_tail = True          # torn record header
                break
            length, crc = _REC.unpack(rec_header)
            payload = fh.read(length)
            if len(payload) < length:
                self.truncated_tail = True          # torn payload
                break
            if pending_bad:
                fh.close()
                raise JournalCorruption(
                    f"{self.path}: CRC mismatch on a non-final record "
                    f"(committed records follow it) — refusing to load")
            if pending is not None:
                self.records.append(pending)
                pending = None
            if zlib.crc32(payload) != crc:
                pending_bad = True
                continue
            try:
                pending = job_codec.decode_value(json.loads(payload))
            except (json.JSONDecodeError, UnicodeDecodeError):
                # valid CRC but undecodable JSON: written corrupt, not
                # torn — same refusal as a mid-file CRC failure
                fh.close()
                raise JournalCorruption(
                    f"{self.path}: record passes CRC but is not valid "
                    f"JSON — refusing to load")
            clean_end = fh.tell()
        if pending_bad:
            # final record failed CRC with nothing after it: a torn tail
            # where the payload bytes happened to land at full length
            self.truncated_tail = True
        elif pending is not None:
            self.records.append(pending)
        if self.truncated_tail and not self._readonly:
            fh.truncate(clean_end)
            self._commit()
        fh.seek(0, os.SEEK_END)

    @staticmethod
    def load(path: str) -> List[Any]:
        """Read-only scan: the recovered records of *path* (same torn-tail
        tolerance as opening, but without keeping a handle or truncating
        the file — safe on a journal another process owns)."""
        j = Journal.__new__(Journal)
        j.path = str(path)
        j.records = []
        j.truncated_tail = False
        j._readonly = True
        j._fh = open(path, "rb")
        try:
            j._scan()
        finally:
            try:
                j._fh.close()
            except (OSError, ValueError):
                pass
        return j.records

    # -- append --------------------------------------------------------
    def _commit(self) -> None:
        self._fh.flush()
        if self.sync:
            os.fsync(self._fh.fileno())

    def append(self, record: Any, sync: Optional[bool] = None) -> None:
        """Encode, write, and (by default) fsync one record. With a
        fault plan armed for this append, writes only half the record's
        bytes and raises :class:`InjectedCrash` — the deterministic
        stand-in for power loss mid-write."""
        data = _encode_record(record)
        with self._lock:
            if self.fault_plan is not None and self.fault_plan.take_record():
                self._fh.write(data[:max(1, len(data) // 2)])
                self._fh.flush()
                os.fsync(self._fh.fileno())
                raise InjectedCrash(
                    f"torn write injected on journal record "
                    f"#{len(self.records) + 1}")
            self._fh.write(data)
            if sync if sync is not None else self.sync:
                self._fh.flush()
                os.fsync(self._fh.fileno())
            else:
                self._fh.flush()
            self.records.append(record)
            self.appended += 1

    def compact(self, records: List[Any]) -> None:
        """Atomically replace the journal's contents with *records*
        (tmp file + fsync + ``os.replace``): either the old journal or
        the new one exists at every instant, never a partial hybrid."""
        tmp = self.path + ".tmp"
        with self._lock:
            with open(tmp, "wb") as out:
                out.write(_HEADER.pack(JOURNAL_MAGIC, JOURNAL_VERSION, 0))
                for record in records:
                    out.write(_encode_record(record))
                out.flush()
                os.fsync(out.fileno())
            os.replace(tmp, self.path)
            try:  # persist the rename itself (best effort on odd FSes)
                dir_fd = os.open(os.path.dirname(os.path.abspath(self.path))
                                 or ".", os.O_RDONLY)
                try:
                    os.fsync(dir_fd)
                finally:
                    os.close(dir_fd)
            except OSError:
                pass
            try:
                self._fh.close()
            except OSError:
                pass
            self._fh = open(self.path, "r+b")
            self._fh.seek(0, os.SEEK_END)
            self.records = list(records)

    def close(self) -> None:
        with self._lock:
            try:
                self._fh.close()
            except OSError:
                pass

    def __len__(self) -> int:
        return len(self.records)

    def stats(self) -> Dict[str, Any]:
        return {"path": self.path, "records": len(self.records),
                "recovered": self.recovered, "appended": self.appended,
                "truncated_tail": self.truncated_tail}


# ----------------------------------------------------------------------
# Typed record constructors. Plain dicts with a "kind" discriminator —
# the journal stores JSON-safe values, so "typed" here means "one
# constructor per record shape, so every writer agrees on field names".
# ----------------------------------------------------------------------

def submit_record(job_id: str, wire: Dict[str, Any], client: str,
                  priority: int, seq: int, created_s: float,
                  attached_to: Optional[str] = None) -> Dict[str, Any]:
    """Service: one accepted submission, committed *before* the 202.
    Carries the full job wire form so recovery can re-enqueue without
    any other state surviving the crash."""
    return {"kind": "submit", "job_id": job_id, "job": wire,
            "client": client, "priority": priority, "seq": seq,
            "created_s": created_s, "attached_to": attached_to}


def terminal_record(job_id: str, state: str,
                    report: Optional[Dict[str, Any]] = None,
                    error: Optional[str] = None,
                    finished_s: float = 0.0) -> Dict[str, Any]:
    """Service: a job reached a terminal state. Carries the report so a
    restart serves completed jobs from the journal instead of re-running
    them."""
    return {"kind": "terminal", "job_id": job_id, "state": state,
            "report": report, "error": error, "finished_s": finished_s}


def wave_record(run_id: int, task_count: int) -> Dict[str, Any]:
    """Coordinator: a ``run_tasks`` wave began. Scopes the dispatch and
    complete records that follow it — recovery only resumes the *last*
    wave (earlier waves either finished or were superseded)."""
    return {"kind": "wave", "run": run_id, "tasks": task_count}


def dispatch_record(run_id: int, task: tuple) -> Dict[str, Any]:
    """Coordinator: one task handed to a worker (journaled on its first
    dispatch; re-dispatches after worker loss aren't new facts). The task
    tuple rides the tuple-tagging codec intact."""
    return {"kind": "dispatch", "run": run_id, "task": task}


def complete_record(run_id: int, idx: int) -> Dict[str, Any]:
    """Coordinator: task *idx* of wave *run_id* merged its result (the
    merge-once point). dispatched − completed = what a restart must
    re-dispatch."""
    return {"kind": "complete", "run": run_id, "idx": idx}
