"""Typed pipeline/engine configuration with a derived cache signature.

Before this module every layer took its own long kwarg list
(``ForgePipeline``/``StageScheduler``/``OptimizationEngine``) and the result
-store cache key depended on a *hand-maintained* signature string in
``pipeline.py`` — a newly added knob that someone forgot to append would
silently poison the cache (results computed under one policy replayed under
another). :class:`ForgeConfig` fixes both:

* one frozen, picklable dataclass carries every knob — the facade, the
  pipeline, the scheduler and the engine all read from it, and because it
  pickles cleanly it is the job/config codec the ROADMAP's process-pool
  follow-up needs;
* :meth:`ForgeConfig.policy_signature` is **derived from the dataclass
  fields**: every field participates unless it is explicitly declared
  operational via ``metadata={"policy": False}``. Adding a knob therefore
  invalidates stale cache entries *by default*; exclusion is a reviewed,
  visible decision, not an omission.

Operational fields (worker count, cache location/size, dump dir) are the
only exclusions: the engine guarantees ``workers=1`` and ``workers=N`` are
result-equivalent, and where a cache lives on disk cannot change what the
pipeline would produce.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["ForgeConfig", "EXECUTION_BACKENDS", "VERIFY_FASTPATH_MODES",
           "PRIOR_POLICIES", "POLICY_SIGNATURE_VERSION"]

# where the engine runs jobs; validated here so a typo'd backend fails at
# config construction, not deep inside a batch
EXECUTION_BACKENDS = ("serial", "thread", "process", "remote")

# how the verifier runs: "off" = the uncached reference cascade, "on" =
# memoized incremental verify + cost-first screening, "check" = memoized and
# cross-checked bit-identical against the uncached path on every report
# (raises on divergence — the fast path's executable contract)
VERIFY_FASTPATH_MODES = ("off", "on", "check")

# how candidate-ordering priors are mined from History: "counts" is the
# legacy flat success-count ordering (bit-exact compatibility mode), "mined"
# uses per-(stage, pattern) statistics + cost-model ranking. Single source
# of truth lives next to the mining code.
from repro.core.history import PRIOR_POLICIES  # noqa: E402

# bumped when the signature *format* changes (field encoding, separator…);
# participates in the signature so format changes can never alias old keys
POLICY_SIGNATURE_VERSION = 1


def _operational(**kw):
    """An operational (non-policy) field: excluded from the cache signature
    because it cannot change what the pipeline produces for a job."""
    return dataclasses.field(metadata={"policy": False}, **kw)


def _search_policy(**kw):
    """A policy field that shapes *search order only*: it participates in
    the exact-result cache signature (a changed ordering can change which
    candidate a fresh search accepts first), but is excluded from the
    *transfer* signature — a transferred TransformLog is re-verified step
    by step at the receiving job, so search-order knobs can never make a
    transferred result wrong, and excluding them keeps family keys (and
    ladder keys) byte-compatible with stores written before the knob
    existed."""
    return dataclasses.field(metadata={"policy": True, "transfer": False},
                             **kw)


def _canon(value) -> str:
    """Canonical, process-stable text form of a field value."""
    if value is None:
        return "*"
    if isinstance(value, bool):
        return "1" if value else "0"
    if isinstance(value, (tuple, list)):
        return ",".join(sorted(str(v) for v in value))
    if isinstance(value, float):
        return repr(value)            # round-trippable, no locale
    return str(value)


@dataclasses.dataclass(frozen=True)
class ForgeConfig:
    """Every knob of the Forge pipeline + fleet engine, in one immutable
    value object.

    Policy fields (all participate in :meth:`policy_signature`):

    * ``spec_name`` — hardware generation (resolved via
      ``repro.hw.specs.get_spec``).
    * ``max_iterations`` — CoVeR iterations per stage (paper's T).
    * ``best_of_k`` — independent pipeline passes, best result kept.
    * ``use_pallas_exec`` — execute Pallas lowerings during verification.
    * ``use_planner`` — dependency-constrained planner vs fixed default
      order (ablation hook).
    * ``warm_start`` — history-driven proposer priors.
    * ``stages_enabled`` — ablation subset (``None`` = all registered
      stages); validated against the stage registry.
    * ``use_llm`` — an LLM client participates in planning/proposals.

    Operational fields (excluded — see module docstring): ``workers``,
    ``execution_backend``, ``cache_path``, ``cache_max_entries``,
    ``dump_dir``, ``verify_fastpath``, ``shared_verify_cache_bytes``,
    ``batch_exec_planning``. ``verify_fastpath`` selects the
    memoized incremental-verification path (``repro.core.verify_cache``),
    which is result-equivalent by contract (its ``"check"`` mode asserts
    bit-identical reports against the uncached cascade), so like the
    backend it stays out of the signature. ``execution_backend`` selects
    *where* jobs run
    (``serial`` in-order on the calling thread, ``thread`` across a bounded
    thread pool, ``process`` across spawned worker processes); the engine
    guarantees all three are result-equivalent, so like ``workers`` it can
    never change what the pipeline produces and stays out of the signature.
    """

    spec_name: str = "tpu_v5e"
    max_iterations: int = 5
    best_of_k: int = 1
    use_pallas_exec: bool = True
    use_planner: bool = True
    warm_start: bool = True
    stages_enabled: Optional[Tuple[str, ...]] = None
    use_llm: bool = False

    # learned-search knobs (policy for the exact cache, excluded from the
    # transfer signature — see _search_policy): how priors are mined from
    # History, and whether stage candidate lists are cost-ranked before the
    # first verification (with early stop once the residual candidates are
    # roofline-dominated)
    prior_policy: str = _search_policy(default="mined")
    cost_rank_proposals: bool = _search_policy(default=True)

    workers: int = _operational(default=1)
    execution_backend: str = _operational(default="thread")
    cache_path: Optional[str] = _operational(default=None)
    cache_max_entries: int = _operational(default=512)
    dump_dir: Optional[str] = _operational(default=None)
    # operational like execution_backend: the fast path is result-equivalent
    # by contract (the "check" mode and the throughput benchmark enforce it),
    # so it can never change what the pipeline produces and stays out of the
    # cache signature — stores built either way replay interchangeably
    verify_fastpath: str = _operational(default="on")
    # byte budget of the engine-owned cross-job SharedVerifyCache (group
    # executions + oracle preps, LRU by bytes); 0 disables sharing. Shared
    # entries are content-addressed, so serving one can never change what a
    # job produces — operational, like verify_fastpath
    shared_verify_cache_bytes: int = _operational(default=64 * 1024 * 1024)
    # pre-execute each duplicated oracle slice once per batch, warming the
    # shared cache before dispatch ("oracle-slice leaders"); planning only
    # reorders *where* an execution happens, never its result
    batch_exec_planning: bool = _operational(default=True)

    # -- distributed fleet knobs (execution_backend="remote") ----------
    # All operational: they shape where and how the fleet runs, never what
    # a job produces (the remote backend is result-equivalent by the same
    # contract as thread/process — gated by scripts/backend_equivalence.py).
    # "host:port" the FleetCoordinator binds for worker connections; None
    # binds 127.0.0.1 on an ephemeral port (loopback fleet). Port 0 asks
    # the OS for a free port; read the resolved one off the coordinator.
    fleet_address: Optional[str] = _operational(default=None)
    # local `forge-worker` processes the coordinator spawns against its own
    # address: None spawns `workers` of them (self-contained loopback
    # fleet), 0 spawns none (external workers connect on their own — the
    # multi-host topology), N spawns exactly N alongside any external ones
    fleet_spawn_workers: Optional[int] = _operational(default=None)
    # how long dispatch waits for the first worker to join before failing
    fleet_connect_timeout_s: float = _operational(default=60.0)
    # coordinator ping cadence; a worker silent for fleet_heartbeat_timeout_s
    # is declared lost and its in-flight job is re-dispatched
    fleet_heartbeat_s: float = _operational(default=2.0)
    fleet_heartbeat_timeout_s: float = _operational(default=10.0)
    # auto-respawn budget for coordinator-spawned workers: after a spawned
    # worker is declared lost, the coordinator relaunches a replacement
    # (capped deterministic backoff) up to this many times across the
    # coordinator's lifetime; 0 disables respawning. Externally launched
    # workers are never respawned — their lifecycle isn't ours.
    fleet_max_respawns: int = _operational(default=3)
    # crash-safe coordinator journal: dispatched task ids and merge-once
    # completions are logged here so a coordinator restart re-dispatches
    # the last wave's unfinished tasks instead of forgetting them; None
    # disables journaling (purely in-memory fleet, the pre-PR-10 behavior)
    fleet_journal_path: Optional[str] = _operational(default=None)
    # deterministic fault injection: a repro.core.faults.FaultPlan in its
    # to_json() form, threaded to the coordinator and its spawned workers
    # (chaos gate / fleet tests only; None = no faults). A JSON string
    # rather than a dict so the frozen config stays hashable; validated
    # by parsing in __post_init__.
    fault_spec: Optional[str] = _operational(default=None)

    def __post_init__(self):
        if self.max_iterations < 1:
            raise ValueError("max_iterations must be >= 1")
        if self.execution_backend not in EXECUTION_BACKENDS:
            raise ValueError(
                f"unknown execution_backend {self.execution_backend!r}; "
                f"choose one of {sorted(EXECUTION_BACKENDS)}")
        if self.verify_fastpath not in VERIFY_FASTPATH_MODES:
            raise ValueError(
                f"unknown verify_fastpath {self.verify_fastpath!r}; "
                f"choose one of {list(VERIFY_FASTPATH_MODES)}")
        if self.prior_policy not in PRIOR_POLICIES:
            raise ValueError(
                f"unknown prior_policy {self.prior_policy!r}; "
                f"choose one of {list(PRIOR_POLICIES)}")
        if self.best_of_k < 1:
            raise ValueError("best_of_k must be >= 1")
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.cache_max_entries < 1:
            raise ValueError("cache_max_entries must be >= 1")
        if self.shared_verify_cache_bytes < 0:
            raise ValueError("shared_verify_cache_bytes must be >= 0 "
                             "(0 disables cross-job sharing)")
        if self.fleet_spawn_workers is not None and self.fleet_spawn_workers < 0:
            raise ValueError("fleet_spawn_workers must be >= 0 "
                             "(None spawns `workers` loopback processes)")
        if self.fleet_connect_timeout_s <= 0:
            raise ValueError("fleet_connect_timeout_s must be > 0")
        if self.fleet_heartbeat_s <= 0:
            raise ValueError("fleet_heartbeat_s must be > 0")
        if self.fleet_heartbeat_timeout_s < self.fleet_heartbeat_s:
            raise ValueError("fleet_heartbeat_timeout_s must be >= "
                             "fleet_heartbeat_s")
        if self.fleet_max_respawns < 0:
            raise ValueError("fleet_max_respawns must be >= 0 "
                             "(0 disables worker auto-respawn)")
        if self.fleet_journal_path is not None:
            object.__setattr__(self, "fleet_journal_path",
                               str(self.fleet_journal_path))
        if self.fault_spec is not None:
            from repro.core.faults import FaultPlan
            FaultPlan.from_json(self.fault_spec)  # fail fast on bad specs
        if self.fleet_address is not None:
            object.__setattr__(self, "fleet_address", str(self.fleet_address))
            from repro.core.remote import parse_address
            parse_address(self.fleet_address)  # fail fast on a bad address
        if self.stages_enabled is not None:
            # normalize list -> tuple so the config stays hashable/picklable
            object.__setattr__(self, "stages_enabled",
                               tuple(self.stages_enabled))
            from repro.core.stages import DEFAULT_REGISTRY
            for s in self.stages_enabled:
                if s not in DEFAULT_REGISTRY:
                    raise ValueError(
                        f"stages_enabled names unknown stage {s!r}; "
                        f"registered: {list(DEFAULT_REGISTRY.names())}")
        if self.cache_path is not None:
            object.__setattr__(self, "cache_path", str(self.cache_path))
        if self.dump_dir is not None:
            object.__setattr__(self, "dump_dir", str(self.dump_dir))

    # ------------------------------------------------------------------
    @classmethod
    def policy_fields(cls) -> List[dataclasses.Field]:
        """The fields that participate in the cache signature (everything
        not explicitly marked ``metadata={"policy": False}``)."""
        return [f for f in dataclasses.fields(cls)
                if f.metadata.get("policy", True)]

    @classmethod
    def operational_fields(cls) -> List[dataclasses.Field]:
        return [f for f in dataclasses.fields(cls)
                if not f.metadata.get("policy", True)]

    def policy_signature(self) -> str:
        """Stable signature of every policy knob, derived from the dataclass
        fields themselves. Sorted by field name so source-order refactors
        don't shuffle cache keys; versioned so format changes can't alias."""
        parts = [f"{f.name}={_canon(getattr(self, f.name))}"
                 for f in sorted(self.policy_fields(), key=lambda f: f.name)]
        return f"forge-v{POLICY_SIGNATURE_VERSION};" + ";".join(parts)

    @classmethod
    def transfer_fields(cls) -> List[dataclasses.Field]:
        """Policy fields that also scope *transfer* (family/ladder) keys —
        everything policy except search-order knobs marked
        ``metadata={"transfer": False}``."""
        return [f for f in cls.policy_fields()
                if f.metadata.get("transfer", True)]

    def transfer_policy_signature(self) -> str:
        """Signature for family/ladder (transfer) keys. Search-order knobs
        are excluded: transferred logs are re-verified step by step, so
        ordering policy can't invalidate a neighbor's trajectory — and for
        the default search knobs this string is byte-identical to the full
        pre-knob signature, keeping stores written before this PR
        transferable."""
        parts = [f"{f.name}={_canon(getattr(self, f.name))}"
                 for f in sorted(self.transfer_fields(),
                                 key=lambda f: f.name)]
        return f"forge-v{POLICY_SIGNATURE_VERSION};" + ";".join(parts)

    # ------------------------------------------------------------------
    def replace(self, **changes) -> "ForgeConfig":
        return dataclasses.replace(self, **changes)

    def to_dict(self) -> dict:
        """Plain-dict codec (JSON-safe) for process-pool job submission."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ForgeConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ForgeConfig fields: {sorted(unknown)}")
        return cls(**d)

    # ------------------------------------------------------------------
    def spec(self):
        """Resolve ``spec_name`` to its :class:`repro.hw.specs.TPUSpec`."""
        from repro.hw.specs import get_spec
        return get_spec(self.spec_name)
