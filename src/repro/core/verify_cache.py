"""Memoized incremental verification: the per-job fast path.

``compile_and_verify`` is the hot loop of the whole system — every candidate
at every stage re-traces the program (``jax.eval_shape``), re-executes it
end-to-end against the oracle, and re-runs the roofline cost model, even
when the candidate only touched one group's tile config. A
:class:`VerifySession` memoizes each of those sub-results keyed by
rename-invariant structural fingerprints (:mod:`repro.ir.fingerprint`):

* **group executions** — oracle-side per-group outputs, keyed Merkle-style
  on the group's local structure, the executor's *effective* dispatch
  signature, and the value fingerprints of every external operand. A
  candidate that mutates one group re-executes only that group and its
  downstream slice; everything upstream replays from the cache.
  Invalidation is purely fingerprint-driven: mutate a group and its key
  (and every downstream key) changes, so stale entries can never be served
  — they just age out.
* **abstract traces** — the syntax gate's ``eval_shape`` is skipped when a
  structurally identical (graph + partition + compute dtype) program
  already traced cleanly. Only successes are cached: failure messages embed
  node names, so failures always re-run.
* **structure checks** — KB constraint sweeps keyed on the exact
  (name-sensitive) program form *plus the KB content hash*, so editing any
  KB YAML invalidates memoized verdicts immediately.
* **cost-model results** — ``ProgramCost`` per exact bench form, shared by
  the per-stage incumbent computation and the performance gate.
* **oracle prep** — seeded inputs/params and the f32 oracle outputs per
  exact graph form, so a replay fallback does not redo the full oracle
  evaluation the replay attempt already paid for.

Sessions are strictly **per job**: leaf value fingerprints bind by name to
the job's seeded input/param arrays, which are only fixed within one
``ProblemContext``. The session auto-clears its value caches if it ever
sees a different binding (defense in depth; the engine wires one session
per job).

``ForgeConfig.verify_fastpath`` selects the mode: ``"off"`` (uncached
reference path), ``"on"`` (memoized + cost-first screening), or ``"check"``
(memoized, and every report is cross-checked bit-identical against the
uncached path — :class:`VerifyFastpathDivergence` on any mismatch).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.config import VERIFY_FASTPATH_MODES
from repro.core.executor import group_exec_signature, group_order, run_group
from repro.ir.fingerprint import (graph_exact_fingerprint, group_fingerprint,
                                  group_value_fingerprint, leaf_fingerprint,
                                  program_exact_fingerprint,
                                  trace_fingerprint)
from repro.ir.schedule import KernelProgram

__all__ = ["VerifySession", "VerifySessionStats", "VerifyFastpathDivergence",
           "VERIFY_FASTPATH_MODES", "run_program_cached"]


class VerifyFastpathDivergence(AssertionError):
    """check-mode caught a fast-path report differing from the reference."""


@dataclasses.dataclass
class VerifySessionStats:
    group_hits: int = 0
    group_misses: int = 0
    trace_hits: int = 0
    trace_misses: int = 0
    structure_hits: int = 0
    structure_misses: int = 0
    cost_hits: int = 0
    cost_misses: int = 0
    oracle_hits: int = 0
    oracle_misses: int = 0
    screened: int = 0           # correctness deferred by the cost screen
    deferred_runs: int = 0      # deferred correctness lazily executed

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class VerifySession:
    """Per-job memo for the verification fast path (see module docstring).

    Not thread-safe by design: the engine runs one job on one worker
    (thread or process), and sessions never cross jobs.
    """

    def __init__(self, max_group_entries: int = 1024):
        self.max_group_entries = max(1, int(max_group_entries))
        self.stats = VerifySessionStats()
        # fp -> [(position-in-group.nodes, array), ...]
        self._groups: Dict[str, List[Tuple[int, Any]]] = {}
        self._traces: set = set()
        self._structure: Dict[Tuple[str, str], List[str]] = {}
        self._costs: Dict[str, Any] = {}
        self._oracle: Dict[str, tuple] = {}
        self._binding_token: Optional[tuple] = None

    # -- binding safety -------------------------------------------------
    def _check_binding(self, inputs, params):
        """Value fingerprints assume one fixed inputs/params binding per
        session. If a different binding ever shows up (misuse: a session
        shared across jobs), drop every value-derived cache."""
        token = (id(inputs), id(params) if params else None)
        if self._binding_token is None:
            self._binding_token = token
        elif self._binding_token != token:
            self._groups.clear()
            self._binding_token = token

    # -- group execution memo -------------------------------------------
    def _get_group(self, fp: str) -> Optional[List[Tuple[int, Any]]]:
        got = self._groups.get(fp)
        if got is not None:
            self.stats.group_hits += 1
        else:
            self.stats.group_misses += 1
        return got

    def _put_group(self, fp: str, outputs: List[Tuple[int, Any]]):
        if len(self._groups) >= self.max_group_entries:
            # FIFO trim: drop the oldest entry (dict order = insertion)
            self._groups.pop(next(iter(self._groups)))
        self._groups[fp] = outputs

    # -- abstract-trace memo --------------------------------------------
    def trace_known_good(self, program: KernelProgram) -> bool:
        fp = trace_fingerprint(program)
        if fp in self._traces:
            self.stats.trace_hits += 1
            return True
        self.stats.trace_misses += 1
        return False

    def record_trace_ok(self, program: KernelProgram):
        self._traces.add(trace_fingerprint(program))

    # -- structure-check memo -------------------------------------------
    def structure_errors(self, program: KernelProgram, ctx, kb,
                         compute) -> List[str]:
        """Memoized KB structure sweep. The key folds in the KB content
        hash (computed per call, not per session), so swapping/editing the
        KB invalidates immediately; the spec is fixed per session via the
        job's ``ProblemContext``."""
        key = (program_exact_fingerprint(program), kb.content_hash())
        got = self._structure.get(key)
        if got is not None:
            self.stats.structure_hits += 1
            return list(got)
        self.stats.structure_misses += 1
        errors = compute(program, ctx, kb)
        self._structure[key] = list(errors)
        return errors

    # -- cost-model memo ------------------------------------------------
    def program_cost(self, cost_model, program: KernelProgram):
        key = program_exact_fingerprint(program)
        got = self._costs.get(key)
        if got is not None:
            self.stats.cost_hits += 1
            return got
        self.stats.cost_misses += 1
        cost = cost_model.program_cost(program)
        self._costs[key] = cost
        return cost

    def program_time(self, cost_model, program: KernelProgram) -> float:
        return self.program_cost(cost_model, program).total_s

    # -- oracle-prep memo -----------------------------------------------
    def oracle_prep(self, graph, compute) -> tuple:
        """Memoized (inputs, params, oracle_outputs) for the trusted
        harness: a replay fallback re-prepares the identical context, so
        the second full oracle evaluation is pure waste."""
        key = graph_exact_fingerprint(graph)
        got = self._oracle.get(key)
        if got is not None:
            self.stats.oracle_hits += 1
            return got
        self.stats.oracle_misses += 1
        prep = compute(graph)
        self._oracle[key] = prep
        return prep


# ----------------------------------------------------------------------
def run_program_cached(program: KernelProgram,
                       inputs: Dict[str, jnp.ndarray],
                       params: Dict[str, jnp.ndarray],
                       session: VerifySession,
                       use_pallas: bool = True,
                       interpret: bool = True) -> Dict[str, jnp.ndarray]:
    """Drop-in for :func:`repro.core.executor.run_program` that replays
    memoized group executions. Produces bit-identical results by
    construction: a group either re-executes through the exact same
    ``run_group`` dispatch, or replays arrays a previous identical dispatch
    produced (JAX CPU execution is deterministic). Cached outputs are
    stored positionally and rebound to the consuming program's node names,
    so renamed structural twins share entries."""
    session._check_binding(inputs, params)
    graph = program.graph
    sched = program.schedule
    compute_dtype = jnp.dtype(sched.compute_dtype)
    env: Dict[str, jnp.ndarray] = {}
    value_fps: Dict[str, str] = {}
    for n in graph.toposorted():
        if n.op == "input":
            env[n.name] = inputs[n.name]
        elif n.op == "param":
            env[n.name] = params[n.name]
        elif n.op == "const":
            env[n.name] = jnp.asarray(n.attrs["value"], jnp.dtype(n.dtype))
        else:
            continue
        value_fps[n.name] = leaf_fingerprint(n)
    for g in group_order(graph, sched.groups):
        sig = group_exec_signature(graph, g, use_pallas=use_pallas)
        gfp = group_fingerprint(graph, g, value_fps,
                                extra=[sig, sched.compute_dtype,
                                       bool(interpret)])
        positions = {name: i for i, name in enumerate(g.nodes)}
        cached = session._get_group(gfp)
        if cached is None:
            out = run_group(graph, g, env, compute_dtype,
                            use_pallas=use_pallas, interpret=interpret)
            session._put_group(gfp, [(positions[k], v)
                                     for k, v in out.items()])
        else:
            out = {g.nodes[i]: v for i, v in cached}
        env.update(out)
        for name in out:
            value_fps[name] = group_value_fingerprint(gfp, positions[name])
    return {o: env[o].astype(jnp.float32) for o in graph.outputs}
