"""Memoized incremental verification: the per-job fast path, plus the
engine-owned cross-job shared layer.

``compile_and_verify`` is the hot loop of the whole system — every candidate
at every stage re-traces the program (``jax.eval_shape``), re-executes it
end-to-end against the oracle, and re-runs the roofline cost model, even
when the candidate only touched one group's tile config. A
:class:`VerifySession` memoizes each of those sub-results keyed by
rename-invariant structural fingerprints (:mod:`repro.ir.fingerprint`):

* **group executions** — oracle-side per-group outputs, keyed Merkle-style
  on the group's local structure, the executor's *effective* dispatch
  signature, and the value fingerprints of every external operand. A
  candidate that mutates one group re-executes only that group and its
  downstream slice; everything upstream replays from the cache.
  Invalidation is purely fingerprint-driven: mutate a group and its key
  (and every downstream key) changes, so stale entries can never be served
  — they just age out.
* **abstract traces** — the syntax gate's ``eval_shape`` is skipped when a
  structurally identical (graph + partition + compute dtype) program
  already traced cleanly. Only successes are cached: failure messages embed
  node names, so failures always re-run.
* **structure checks** — KB constraint sweeps keyed on the exact
  (name-sensitive) program form *plus the KB content hash*, so editing any
  KB YAML invalidates memoized verdicts immediately.
* **cost-model results** — ``ProgramCost`` per exact bench form, shared by
  the per-stage incumbent computation and the performance gate.
* **oracle prep** — seeded inputs/params and the f32 oracle outputs per
  exact graph form, so a replay fallback does not redo the full oracle
  evaluation the replay attempt already paid for.

Cross-job sharing
-----------------
Leaf value fingerprints are **content-addressed**
(:func:`repro.ir.fingerprint.content_leaf_fingerprint`): an input/param leaf
hashes the bytes of the array actually bound to it, not its name, so two
jobs whose groups consume bit-identical values produce identical group keys
regardless of which job seeded them. That makes group executions and oracle
preps safely shareable across jobs through a :class:`SharedVerifyCache` —
an engine-owned, byte-capped LRU (sharded locks like ``ResultStore``) that
each per-job session treats as a read-through/write-back layer. Oracle
preps are stored as *positional* array lists keyed on the rename-invariant
canonical graph form and rebound to the consuming graph's names on reuse
(see :func:`repro.ir.fingerprint.graph_oracle_fingerprint` for why the
positions line up).

Per-session memos carry the same byte-cap discipline (FIFO trim over
``max_group_bytes``/``max_oracle_bytes``) so a pathological batch cannot
OOM a worker by accumulating unbounded oracle prep or group outputs.

``ForgeConfig.verify_fastpath`` selects the mode: ``"off"`` (uncached
reference path), ``"on"`` (memoized + cost-first screening), or ``"check"``
(memoized, and every report is cross-checked bit-identical against the
uncached path — :class:`VerifyFastpathDivergence` on any mismatch). In
check mode a session also validates every *shared-cache* hit byte-exact
against a fresh local execution before adopting it — corrupt or stale
shared entries surface as :class:`VerifyFastpathDivergence` at the exact
group/prep that diverged, not as a downstream numeric drift.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.config import VERIFY_FASTPATH_MODES
from repro.core.executor import group_exec_signature, group_order, run_group
from repro.ir.fingerprint import (content_leaf_fingerprint,
                                  graph_exact_fingerprint,
                                  graph_oracle_fingerprint, group_fingerprint,
                                  group_value_fingerprint, leaf_fingerprint,
                                  program_exact_fingerprint,
                                  trace_fingerprint)
from repro.ir.schedule import KernelProgram

__all__ = ["VerifySession", "VerifySessionStats", "VerifyFastpathDivergence",
           "SharedVerifyCache", "VERIFY_FASTPATH_MODES", "run_program_cached"]

#: Per-session memo byte caps (groups / oracle preps each). Generous — a
#: typical job stays in the low tens of MB — but bounded, so a worker can
#: never be OOMed by one pathological batch.
DEFAULT_SESSION_BYTES = 256 * 1024 * 1024


class VerifyFastpathDivergence(AssertionError):
    """check-mode caught a fast-path report differing from the reference."""


def _value_nbytes(value) -> int:
    """Total array payload bytes of a cache value (group output list,
    positional oracle slice, or any nesting of lists/tuples/dicts)."""
    total = 0
    stack = [value]
    while stack:
        v = stack.pop()
        if isinstance(v, (list, tuple)):
            stack.extend(v)
        elif isinstance(v, dict):
            stack.extend(v.values())
        elif hasattr(v, "nbytes"):
            total += int(v.nbytes)
    return total


def _bytes_equal(a, b) -> bool:
    na, nb = np.asarray(a), np.asarray(b)
    return (na.dtype == nb.dtype and na.shape == nb.shape
            and na.tobytes() == nb.tobytes())


# ----------------------------------------------------------------------
# engine-owned shared layer
# ----------------------------------------------------------------------

class _Shard:
    __slots__ = ("lock", "entries")

    def __init__(self):
        self.lock = threading.Lock()
        # key -> [seq, value, nbytes]
        self.entries: Dict[tuple, list] = {}


class SharedVerifyCache:
    """Byte-capped LRU for verification artifacts, shared across jobs.

    Keys are ``("group", group_fp)`` (value: positional group outputs) and
    ``("oracle", oracle_fp)`` (value: positional prep slice). Thread-safe
    with the same sharded-lock structure as ``ResultStore``: CRC32-routed
    shards, a store-wide monotonic stamp sequence, and a lazy ``(seq, key)``
    min-heap for recency (stale stamps are skipped at eviction; the heap is
    compacted in place when it outgrows the live entry count). Lock order:
    evict > shard > seq.

    ``put`` refuses values larger than the whole cap outright — inserting
    and immediately self-evicting would just churn every other entry out.
    """

    def __init__(self, max_bytes: int, shards: int = 8):
        self.max_bytes = max(0, int(max_bytes))
        self._shards = tuple(_Shard() for _ in range(max(1, int(shards))))
        self._seq_lock = threading.Lock()
        self._evict_lock = threading.Lock()
        self._seq = 0
        self._count = 0
        self._bytes = 0
        self._recency: List[Tuple[int, tuple]] = []
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- internals ------------------------------------------------------
    def _shard(self, key: tuple) -> _Shard:
        return self._shards[zlib.crc32(repr(key).encode())
                            % len(self._shards)]

    def _stamp(self, key: tuple) -> int:
        """Allocate a recency stamp (caller may hold a shard lock; shard >
        seq is the documented order)."""
        with self._seq_lock:
            self._seq += 1
            heapq.heappush(self._recency, (self._seq, key))
            if len(self._recency) > max(64, 8 * self._count):
                # drop stale duplicate stamps in place: keep only the
                # newest stamp per key (no shard locks needed — dead keys
                # are skipped at eviction anyway)
                best: Dict[tuple, int] = {}
                for seq, k in self._recency:
                    if best.get(k, -1) < seq:
                        best[k] = seq
                self._recency = [(s, k) for k, s in best.items()]
                heapq.heapify(self._recency)
            return self._seq

    # -- public surface -------------------------------------------------
    def get(self, key: tuple):
        shard = self._shard(key)
        with shard.lock:
            rec = shard.entries.get(key)
            if rec is None:
                with self._seq_lock:
                    self.misses += 1
                return None
            rec[0] = self._stamp(key)
            value = rec[1]
        with self._seq_lock:
            self.hits += 1
        return value

    def put(self, key: tuple, value) -> bool:
        nbytes = _value_nbytes(value)
        if self.max_bytes <= 0 or nbytes > self.max_bytes:
            return False
        shard = self._shard(key)
        with shard.lock:
            rec = shard.entries.get(key)
            if rec is not None:
                delta = nbytes - rec[2]
                rec[0] = self._stamp(key)
                rec[1] = value
                rec[2] = nbytes
                with self._seq_lock:
                    self._bytes += delta
            else:
                shard.entries[key] = [self._stamp(key), value, nbytes]
                with self._seq_lock:
                    self._count += 1
                    self._bytes += nbytes
        self._evict()
        return True

    def _evict(self):
        with self._evict_lock:
            while True:
                with self._seq_lock:
                    if self._bytes <= self.max_bytes or not self._recency:
                        return
                    seq, key = heapq.heappop(self._recency)
                shard = self._shard(key)
                with shard.lock:
                    rec = shard.entries.get(key)
                    if rec is None or rec[0] != seq:
                        continue  # refreshed or already gone: stale stamp
                    shard.entries.pop(key)
                    with self._seq_lock:
                        self._count -= 1
                        self._bytes -= rec[2]
                        self.evictions += 1

    def total_bytes(self) -> int:
        with self._seq_lock:
            return self._bytes

    def __len__(self) -> int:
        with self._seq_lock:
            return self._count

    def __contains__(self, key: tuple) -> bool:
        shard = self._shard(key)
        with shard.lock:
            return key in shard.entries

    def clear(self):
        for shard in self._shards:
            shard.lock.acquire()
        try:
            for shard in self._shards:
                shard.entries.clear()
            with self._seq_lock:
                self._count = 0
                self._bytes = 0
                self._recency = []
        finally:
            for shard in self._shards:
                shard.lock.release()

    def stats_dict(self) -> Dict[str, int]:
        with self._seq_lock:
            return {"entries": self._count, "bytes": self._bytes,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}


# ----------------------------------------------------------------------
# oracle-prep positional slices (cross-graph rebinding)
# ----------------------------------------------------------------------

def _oracle_slice(graph, prep) -> tuple:
    """Name-free positional form of a prep triple, storable under the
    rename-invariant oracle key: inputs in ``graph.inputs()`` order, params
    in ``graph.params()`` order, oracle outputs in ``graph.outputs`` order."""
    inputs, params, oracle = prep
    return ([inputs[n.name] for n in graph.inputs()],
            [params[n.name] for n in graph.params()],
            [oracle[o] for o in graph.outputs])


def _rebind_oracle_slice(graph, slice_) -> Optional[tuple]:
    """Rebind a positional slice to ``graph``'s own names. Canonical-equal
    graphs agree positionally by construction; a length mismatch means the
    slice cannot belong to this key — treat as a miss, never guess."""
    ins, ps, outs = slice_
    in_nodes, p_nodes = graph.inputs(), graph.params()
    if (len(ins) != len(in_nodes) or len(ps) != len(p_nodes)
            or len(outs) != len(graph.outputs)):
        return None
    return ({n.name: a for n, a in zip(in_nodes, ins)},
            {n.name: a for n, a in zip(p_nodes, ps)},
            dict(zip(graph.outputs, outs)))


@dataclasses.dataclass
class VerifySessionStats:
    group_hits: int = 0
    group_misses: int = 0
    trace_hits: int = 0
    trace_misses: int = 0
    structure_hits: int = 0
    structure_misses: int = 0
    cost_hits: int = 0
    cost_misses: int = 0
    oracle_hits: int = 0
    oracle_misses: int = 0
    screened: int = 0           # correctness deferred by the cost screen
    deferred_runs: int = 0      # deferred correctness lazily executed
    shared_group_hits: int = 0  # group executions served by the shared layer
    shared_oracle_hits: int = 0  # oracle preps rebound from the shared layer

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class VerifySession:
    """Per-job memo for the verification fast path (see module docstring).

    Not thread-safe by design: the engine runs one job on one worker
    (thread or process), and sessions never cross jobs. The optional
    ``shared`` :class:`SharedVerifyCache` *is* thread-safe and is the only
    state that crosses jobs — the session reads through it on local misses
    and writes back everything it executes.
    """

    def __init__(self, max_group_entries: int = 1024,
                 shared: Optional[SharedVerifyCache] = None,
                 check_shared: bool = False,
                 max_group_bytes: int = DEFAULT_SESSION_BYTES,
                 max_oracle_bytes: int = DEFAULT_SESSION_BYTES):
        self.max_group_entries = max(1, int(max_group_entries))
        self.max_group_bytes = max(1, int(max_group_bytes))
        self.max_oracle_bytes = max(1, int(max_oracle_bytes))
        self.stats = VerifySessionStats()
        self._shared = shared
        self.check_shared = bool(check_shared)
        # fp -> [(position-in-group.nodes, array), ...]
        self._groups: Dict[str, List[Tuple[int, Any]]] = {}
        self._groups_nbytes: Dict[str, int] = {}
        self._groups_total = 0
        self._traces: set = set()
        self._structure: Dict[Tuple[str, str], List[str]] = {}
        self._costs: Dict[str, Any] = {}
        self._oracle: Dict[str, tuple] = {}
        self._oracle_nbytes: Dict[str, int] = {}
        self._oracle_total = 0

    # -- group execution memo -------------------------------------------
    def _get_group(self, fp: str) -> Optional[List[Tuple[int, Any]]]:
        got = self._groups.get(fp)
        if got is not None:
            self.stats.group_hits += 1
        else:
            self.stats.group_misses += 1
        return got

    def _put_group(self, fp: str, outputs: List[Tuple[int, Any]]):
        if fp in self._groups:
            return
        nbytes = _value_nbytes(outputs)
        self._groups[fp] = outputs
        self._groups_nbytes[fp] = nbytes
        self._groups_total += nbytes
        # FIFO trim over either cap (dict order = insertion); the entry
        # just inserted is never trimmed, so progress is always possible
        while len(self._groups) > 1 and (
                len(self._groups) > self.max_group_entries
                or self._groups_total > self.max_group_bytes):
            old = next(iter(self._groups))
            if old == fp:
                break
            self._groups.pop(old)
            self._groups_total -= self._groups_nbytes.pop(old)

    def _get_group_shared(self, fp: str, validate=None):
        """Read-through to the shared layer on a local miss. In check mode
        ``validate`` re-executes the group locally and byte-compares before
        the entry is adopted."""
        if self._shared is None:
            return None
        got = self._shared.get(("group", fp))
        if got is None:
            return None
        if self.check_shared and validate is not None:
            validate(got)
        self.stats.shared_group_hits += 1
        self._put_group(fp, got)
        return got

    # -- abstract-trace memo --------------------------------------------
    def trace_known_good(self, program: KernelProgram) -> bool:
        fp = trace_fingerprint(program)
        if fp in self._traces:
            self.stats.trace_hits += 1
            return True
        self.stats.trace_misses += 1
        return False

    def record_trace_ok(self, program: KernelProgram):
        self._traces.add(trace_fingerprint(program))

    # -- structure-check memo -------------------------------------------
    def structure_errors(self, program: KernelProgram, ctx, kb,
                         compute) -> List[str]:
        """Memoized KB structure sweep. The key folds in the KB content
        hash (computed per call, not per session), so swapping/editing the
        KB invalidates immediately; the spec is fixed per session via the
        job's ``ProblemContext``."""
        key = (program_exact_fingerprint(program), kb.content_hash())
        got = self._structure.get(key)
        if got is not None:
            self.stats.structure_hits += 1
            return list(got)
        self.stats.structure_misses += 1
        errors = compute(program, ctx, kb)
        self._structure[key] = list(errors)
        return errors

    # -- cost-model memo ------------------------------------------------
    def program_cost(self, cost_model, program: KernelProgram):
        key = program_exact_fingerprint(program)
        got = self._costs.get(key)
        if got is not None:
            self.stats.cost_hits += 1
            return got
        self.stats.cost_misses += 1
        cost = cost_model.program_cost(program)
        self._costs[key] = cost
        return cost

    def program_time(self, cost_model, program: KernelProgram) -> float:
        return self.program_cost(cost_model, program).total_s

    # -- oracle-prep memo -----------------------------------------------
    def _put_oracle(self, key: str, prep: tuple):
        if key in self._oracle:
            return
        nbytes = _value_nbytes(prep)
        self._oracle[key] = prep
        self._oracle_nbytes[key] = nbytes
        self._oracle_total += nbytes
        while (len(self._oracle) > 1
               and self._oracle_total > self.max_oracle_bytes):
            old = next(iter(self._oracle))
            if old == key:
                break
            self._oracle.pop(old)
            self._oracle_total -= self._oracle_nbytes.pop(old)

    def oracle_prep(self, graph, compute) -> tuple:
        """Memoized (inputs, params, oracle_outputs) for the trusted
        harness: a replay fallback re-prepares the identical context, so
        the second full oracle evaluation is pure waste. On a local miss
        the shared layer is probed under the rename-invariant oracle key —
        a hit rebinds the positional slice to this graph's names, so
        renamed family twins across jobs share one oracle evaluation."""
        key = graph_exact_fingerprint(graph)
        got = self._oracle.get(key)
        if got is not None:
            self.stats.oracle_hits += 1
            return got
        self.stats.oracle_misses += 1
        prep = None
        okey = None
        if self._shared is not None:
            okey = ("oracle", graph_oracle_fingerprint(graph))
            slice_ = self._shared.get(okey)
            if slice_ is not None:
                prep = _rebind_oracle_slice(graph, slice_)
                if prep is not None:
                    if self.check_shared:
                        self._validate_shared_oracle(graph, compute, prep)
                    self.stats.shared_oracle_hits += 1
        if prep is None:
            prep = compute(graph)
            if self._shared is not None:
                self._shared.put(okey, _oracle_slice(graph, prep))
        self._put_oracle(key, prep)
        return prep

    def _validate_shared_oracle(self, graph, compute, prep):
        """check mode: a shared oracle hit must be byte-identical to a
        fresh local prep — positionally rebound arrays that drifted mean a
        corrupt cache or a fingerprint collision, and either must fail
        loudly, not skew every downstream correctness verdict."""
        ref = compute(graph)
        for label, got_d, ref_d in zip(("inputs", "params", "oracle"),
                                       prep, ref):
            if set(got_d) != set(ref_d):
                raise VerifyFastpathDivergence(
                    f"shared oracle prep {label} names diverged: "
                    f"{sorted(got_d)} vs {sorted(ref_d)}")
            for name in ref_d:
                if not _bytes_equal(got_d[name], ref_d[name]):
                    raise VerifyFastpathDivergence(
                        f"shared oracle prep diverged at {label}[{name!r}]")


# ----------------------------------------------------------------------
def run_program_cached(program: KernelProgram,
                       inputs: Dict[str, jnp.ndarray],
                       params: Dict[str, jnp.ndarray],
                       session: VerifySession,
                       use_pallas: bool = True,
                       interpret: bool = True) -> Dict[str, jnp.ndarray]:
    """Drop-in for :func:`repro.core.executor.run_program` that replays
    memoized group executions. Produces bit-identical results by
    construction: a group either re-executes through the exact same
    ``run_group`` dispatch, or replays arrays a previous identical dispatch
    produced (JAX CPU execution is deterministic). Cached outputs are
    stored positionally and rebound to the consuming program's node names,
    so renamed structural twins share entries — input/param leaves are
    content-addressed (the bytes of the bound array, not its name), which
    extends that sharing across *jobs* through ``session``'s optional
    :class:`SharedVerifyCache`."""
    graph = program.graph
    sched = program.schedule
    compute_dtype = jnp.dtype(sched.compute_dtype)
    env: Dict[str, jnp.ndarray] = {}
    value_fps: Dict[str, str] = {}
    for n in graph.toposorted():
        if n.op == "input":
            env[n.name] = inputs[n.name]
        elif n.op == "param":
            env[n.name] = params[n.name]
        elif n.op == "const":
            env[n.name] = jnp.asarray(n.attrs["value"], jnp.dtype(n.dtype))
            value_fps[n.name] = leaf_fingerprint(n)
            continue
        else:
            continue
        value_fps[n.name] = content_leaf_fingerprint(n, env[n.name])
    for g in group_order(graph, sched.groups):
        sig = group_exec_signature(graph, g, use_pallas=use_pallas)
        gfp = group_fingerprint(graph, g, value_fps,
                                extra=[sig, sched.compute_dtype,
                                       bool(interpret)])
        positions = {name: i for i, name in enumerate(g.nodes)}
        cached = session._get_group(gfp)
        if cached is None:
            def _validate(entry, _g=g, _gfp=gfp):
                ref = run_group(graph, _g, env, compute_dtype,
                                use_pallas=use_pallas, interpret=interpret)
                want = {_g.nodes[i]: v for i, v in entry}
                if set(want) != set(ref):
                    raise VerifyFastpathDivergence(
                        f"shared group {_gfp[:12]} output names diverged")
                for name, v in want.items():
                    if not _bytes_equal(v, ref[name]):
                        raise VerifyFastpathDivergence(
                            f"shared group {_gfp[:12]} diverged at "
                            f"output {name!r}")
            cached = session._get_group_shared(gfp, validate=_validate)
        if cached is None:
            out = run_group(graph, g, env, compute_dtype,
                            use_pallas=use_pallas, interpret=interpret)
            entry = [(positions[k], v) for k, v in out.items()]
            session._put_group(gfp, entry)
            if session._shared is not None:
                session._shared.put(("group", gfp), entry)
        else:
            out = {g.nodes[i]: v for i, v in cached}
        env.update(out)
        for name in out:
            value_fps[name] = group_value_fingerprint(gfp, positions[name])
    return {o: env[o].astype(jnp.float32) for o in graph.outputs}
