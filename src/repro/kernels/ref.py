"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the verification cascade (and every kernel test)
compares against. No tiling, no scheduling — just the math.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.kernels.epilogue import EpilogueOp, apply_epilogue


# ----------------------------------------------------------------------
def matmul_fused_ref(a: jnp.ndarray, b: jnp.ndarray,
                     epilogue: Optional[List[EpilogueOp]] = None,
                     operands: Optional[Dict[str, jnp.ndarray]] = None,
                     transpose_b: bool = False,
                     reduction: Optional[str] = None) -> jnp.ndarray:
    if transpose_b:
        b = b.T
    y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = apply_epilogue(y, epilogue or [], operands or {})
    if reduction == "sum":
        y = jnp.sum(y, axis=-1)
    elif reduction == "max":
        y = jnp.max(y, axis=-1)
    elif reduction == "min":
        y = jnp.min(y, axis=-1)
    elif reduction == "mean":
        y = jnp.mean(y, axis=-1)
    return y


# ----------------------------------------------------------------------
def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = False, scale: Optional[float] = None,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Full-softmax attention oracle. q,k,v: [B, H, S, D] (H may be grouped
    outside). Computes in f32."""
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    sq, skv = q.shape[-2], k.shape[-2]
    if causal or window is not None:
        qi = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (decode-friendly)
        ki = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= ki > qi - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: Optional[jnp.ndarray] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode oracle. q: [B, H, D]; k,v: [B, H, S, D];
    lengths: [B] valid KV lengths (None = all valid)."""
    q32 = q.astype(jnp.float32)
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhd,bhsd->bhs", q32, k32) * scale
    if lengths is not None:
        mask = jnp.arange(k.shape[-2])[None, None, :] < lengths[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, v32)


# ----------------------------------------------------------------------
def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
def elementwise_chain_ref(x: jnp.ndarray, epilogue: List[EpilogueOp],
                          operands: Optional[Dict[str, jnp.ndarray]] = None
                          ) -> jnp.ndarray:
    return apply_epilogue(x.astype(jnp.float32), epilogue, operands or {}).astype(x.dtype)


# ----------------------------------------------------------------------
def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
            b: jnp.ndarray, c: jnp.ndarray,
            initial_state: Optional[jnp.ndarray] = None):
    """Mamba-2 SSD oracle (sequential scan, exact).

    x:  [B, L, H, P]   token inputs per head
    dt: [B, L, H]      softplus-ed step sizes (>0)
    a:  [H]            negative state decay rate per head
    b:  [B, L, N]      input projection (shared across heads, G=1)
    c:  [B, L, N]      output projection
    returns y: [B, L, H, P], final_state: [B, H, P, N]
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    b32, c32 = b.astype(jnp.float32), c.astype(jnp.float32)
    a32 = a.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp          # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * a32[None, :])            # [B,H]
        dbx = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        state = state * decay[..., None, None] + dbx   # [B,H,P,N]
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    state0 = (initial_state.astype(jnp.float32) if initial_state is not None
              else jnp.zeros((B, H, P, N), jnp.float32))
    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd_chunked_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, chunk: int = 128,
                    initial_state: Optional[jnp.ndarray] = None):
    """Chunked SSD in pure jnp — the same intra/inter-chunk decomposition as
    the Pallas kernel, vectorized over chunks. Training-friendly: backward
    saves O(L/chunk) states instead of O(L) (the sequential ``ssd_ref``
    backward is O(L) and explodes at 4k+ sequence lengths)."""
    B, L, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    x32 = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dt32 = dt.astype(jnp.float32).reshape(B, nc, Q, H)
    b32 = b.astype(jnp.float32).reshape(B, nc, Q, N)
    c32 = c.astype(jnp.float32).reshape(B, nc, Q, N)
    a32 = a.astype(jnp.float32)

    aq = dt32 * a32[None, None, None, :]                 # [B, nc, Q, H]
    cums = jnp.cumsum(aq, axis=2)

    # intra-chunk: masked decay-weighted attention (per chunk, batched)
    scores = jnp.einsum("bcqn,bcsn->bcqs", c32, b32)      # [B, nc, Q, Q]
    # li[b,c,q,s,h] = cums[q] - cums[s]
    li = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # [B, nc, Q, S, H]
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    w = scores[..., None] * decay * dt32[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, x32)

    # chunk-boundary states: S_c' = exp(total) S_c + ds_c
    total = cums[:, :, -1, :]                             # [B, nc, H]
    wgt = jnp.exp(total[:, :, None, :] - cums) * dt32     # [B, nc, Q, H]
    ds = jnp.einsum("bcqhp,bcqn,bcqh->bchpn", x32, b32, wgt)

    def chunk_step(state, inp):
        tot, ds_c = inp                                    # [B,H], [B,H,P,N]
        out = state                                        # state entering chunk
        new = state * jnp.exp(tot)[..., None, None] + ds_c
        return new, out

    state0 = (initial_state.astype(jnp.float32) if initial_state is not None
              else jnp.zeros((B, H, P, N), jnp.float32))
    final, entry_states = jax.lax.scan(
        chunk_step, state0,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(ds, 1, 0)))
    entry = jnp.moveaxis(entry_states, 0, 1)               # [B, nc, H, P, N]

    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", c32, entry) \
        * jnp.exp(cums)[..., None]
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y.astype(x.dtype), final
