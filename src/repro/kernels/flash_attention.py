"""Flash-attention Pallas kernels (forward).

* :func:`flash_attention` — online-softmax tiled kernel: grid over
  (batch*heads, q-tiles, kv-tiles) with kv innermost/arbitrary; running
  (m, l, acc) statistics live in VMEM scratch across kv steps (the
  persistent-row-reduction pattern). Supports causal masking, local
  (sliding-window) masking, and GQA via a head-mapping index.

* :func:`attention_unoptimized` — the "original" kernel the pipeline starts
  from (paper Fig. 14 blue bars): per q-tile it loads the FULL K/V into VMEM
  and materializes the full score row — correct, VMEM-hungry, unpipelined.

Shapes: q [B, H, Sq, D], k/v [B, Hkv, Skv, D]; Hkv divides H.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import CompilerParams

NEG_INF = -1e30


def _cdiv(a, b):
    return -(-a // b)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    acc_dtype=jnp.float32,
                    interpret: bool = True) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    q_per_kv = h // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    qt, kt = _cdiv(sq, block_q), _cdiv(skv, block_kv)
    # align query/key positions at the sequence end (prefill & decode agree)
    off = skv - sq

    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        qi, kj = pl.program_id(1), pl.program_id(2)

        @pl.when(kj == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        qv = q_ref[0].astype(acc_dtype)          # [bq, d]
        kv_ = k_ref[0].astype(acc_dtype)         # [bkv, d]
        s = jax.lax.dot_general(qv, kv_, (((1,), (1,)), ((), ())),
                                preferred_element_type=acc_dtype) * scale

        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + off
        kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < skv  # ragged tail
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                       # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(acc_dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype)
        m_ref[...] = m_new

        @pl.when(kj == kt - 1)
        def _():
            l = l_ref[...]
            l = jnp.where(l == 0.0, 1.0, l)       # fully-masked rows -> 0 output
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_head(bh):  # map flat q-head index -> flat kv-head index
        return (bh // h) * hkv + (bh % h) // q_per_kv

    out = pl.pallas_call(
        kernel,
        grid=(b * h, qt, kt),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, qi, kj: (kv_head(bh), kj, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, qi, kj: (kv_head(bh), kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), acc_dtype),
                        pltpu.VMEM((block_q, 1), acc_dtype),
                        pltpu.VMEM((block_q, d), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)


def attention_unoptimized(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                          causal: bool = False,
                          scale: Optional[float] = None,
                          block_q: int = 128,
                          interpret: bool = True) -> jnp.ndarray:
    """The KernelFalcon-style starting point: full-KV per q-tile, full score
    materialization, single-pass softmax. O(Skv) VMEM per program."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    q_per_kv = h // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    block_q = min(block_q, sq)
    qt = _cdiv(sq, block_q)
    off = skv - sq

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qi = pl.program_id(1)
        qv = q_ref[0].astype(jnp.float32)
        kv_ = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(qv, kv_, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + off
            kpos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_ref[0] = jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(o_ref.dtype)

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_head(bh):
        return (bh // h) * hkv + (bh % h) // q_per_kv

    out = pl.pallas_call(
        kernel,
        grid=(b * h, qt),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, skv, d), lambda bh, qi: (kv_head(bh), 0, 0)),
            pl.BlockSpec((1, skv, d), lambda bh, qi: (kv_head(bh), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
