"""Fused elementwise-chain Pallas kernel.

Executes an epilogue chain (k pointwise ops) in one pass: one HBM read per
input, one write — the fusion stage's product for chains with no contraction.
x: [R, C] (leading dims flattened by the ops wrapper).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.compat import CompilerParams

from repro.kernels.epilogue import EpilogueOp, apply_epilogue
from repro.kernels.matmul_fused import _normalize_operand, _operand_spec


def _cdiv(a, b):
    return -(-a // b)


def elementwise_chain(x: jnp.ndarray, epilogue: List[EpilogueOp], *,
                      operands: Optional[Dict[str, jnp.ndarray]] = None,
                      block_rows: int = 256,
                      out_dtype=None,
                      interpret: bool = True) -> jnp.ndarray:
    operands = operands or {}
    r, c = x.shape
    out_dtype = out_dtype or x.dtype
    block_rows = min(block_rows, r)
    rt = _cdiv(r, block_rows)

    op_names = sorted({e.operand for e in epilogue if e.operand is not None})
    norm_ops = {s: _normalize_operand(s, operands[s], r, c) for s in op_names}

    def kernel(x_ref, *rest):
        op_refs, o_ref = rest[:len(op_names)], rest[len(op_names)]
        tile_ops = {s: ref[...] for s, ref in zip(op_names, op_refs)}
        y = apply_epilogue(x_ref[...].astype(jnp.float32), epilogue, tile_ops)
        o_ref[...] = y.astype(o_ref.dtype)

    m_of = lambda i: i
    n_of = lambda i: 0
    in_specs = [pl.BlockSpec((block_rows, c), lambda i: (i, 0))]
    in_specs += [_operand_spec(norm_ops[s], block_rows, c, m_of, n_of)
                 for s in op_names]

    return pl.pallas_call(
        kernel,
        grid=(rt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), out_dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, *[norm_ops[s] for s in op_names])
