"""Split-KV decode attention (flash-decoding) Pallas kernel.

Decode shapes (q_len=1, huge KV) leave the q-tile grid of a prefill kernel
with no parallelism; the decode kernel instead parallelizes over KV blocks and
merges partial softmax statistics — the persistent-row-reduction pattern
applied along KV. Supports GQA (q heads grouped over kv heads) and per-batch
valid lengths (paged-cache-style ragged KV).

q: [B, H, D]; k/v: [B, Hkv, S, D]; lengths: [B] or None.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import CompilerParams

NEG_INF = -1e30


def _cdiv(a, b):
    return -(-a // b)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     lengths: Optional[jnp.ndarray] = None,
                     scale: Optional[float] = None,
                     block_kv: int = 512,
                     acc_dtype=jnp.float32,
                     interpret: bool = True) -> jnp.ndarray:
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    assert h % hkv == 0
    q_per_kv = h // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    block_kv = min(block_kv, s)
    kt = _cdiv(s, block_kv)
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    lengths2d = lengths.reshape(b, 1).astype(jnp.int32)

    # group q heads that share a kv head into one tile: [B*Hkv, q_per_kv, D]
    qf = q.reshape(b, hkv, q_per_kv, d).reshape(b * hkv, q_per_kv, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    def kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        kj = pl.program_id(1)

        @pl.when(kj == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        qv = q_ref[0].astype(acc_dtype)                  # [qpk, d]
        kv_ = k_ref[0].astype(acc_dtype)                 # [bkv, d]
        st = jax.lax.dot_general(qv, kv_, (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc_dtype) * scale
        kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, st.shape, 1)
        valid = kpos < len_ref[0, 0]
        st = jnp.where(valid, st, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(st, axis=-1, keepdims=True))
        p = jnp.exp(st - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(acc_dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype)
        m_ref[...] = m_new

        @pl.when(kj == kt - 1)
        def _():
            l = l_ref[...]
            l = jnp.where(l == 0.0, 1.0, l)
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b * hkv, kt),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, kj: (bh // hkv, 0)),
            pl.BlockSpec((1, q_per_kv, d), lambda bh, kj: (bh, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, kj: (bh, kj, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, kj: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_per_kv, d), lambda bh, kj: (bh, 0, 0)),
        scratch_shapes=[pltpu.VMEM((q_per_kv, 1), acc_dtype),
                        pltpu.VMEM((q_per_kv, 1), acc_dtype),
                        pltpu.VMEM((q_per_kv, d), acc_dtype)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, q_per_kv, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths2d, qf, kf, vf)
    return out.reshape(b, h, d)
