"""Pallas TPU kernels (validated in interpret mode on CPU; TPU is the target).

Layout: one <name>.py per kernel (pl.pallas_call + BlockSpec), with
``ops.py`` as the jit'd wrapper layer and ``ref.py`` as the pure-jnp oracles.
"""

from repro.kernels.epilogue import EpilogueOp
from repro.kernels import ref
from repro.kernels import ops

__all__ = ["EpilogueOp", "ref", "ops"]
