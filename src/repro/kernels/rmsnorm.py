"""Fused single-pass RMSNorm Pallas kernel.

x: [R, d] (leading dims flattened by the ops wrapper), w: [d].
One read, one write; f32 math regardless of io dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.compat import CompilerParams


def _cdiv(a, b):
    return -(-a // b)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    r, d = x.shape
    block_rows = min(block_rows, r)
    rt = _cdiv(r, block_rows)

    def kernel(x_ref, w_ref, o_ref):
        xv = x_ref[...].astype(jnp.float32)
        var = jnp.mean(jnp.square(xv), axis=-1, keepdims=True)
        y = xv * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(rt,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w.reshape(1, d))
