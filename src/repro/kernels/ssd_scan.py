"""Mamba-2 SSD chunked-scan Pallas kernel (state-space duality).

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the sequence is
processed in chunks; within a chunk the recurrence is expressed as a masked
"attention-like" matmul (MXU-friendly), and the chunk-to-chunk state [P, N]
is carried in VMEM scratch across grid steps (persistent-accumulator pattern,
chunk dim marked arbitrary).

Flattened shapes (ops wrapper handles [B, L, H, ...] -> [B*H, L, ...]):
  x  [BH, L, P]   per-head inputs
  dt [BH, L]      positive step sizes
  a  [BH, 1]      negative per-head decay rate
  b  [BH, L, N]   input projection
  c  [BH, L, N]   output projection
Returns y [BH, L, P], final_state [BH, P, N].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import CompilerParams


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, *,
             chunk: int = 128,
             interpret: bool = True):
    bh, l, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, f"seq len {l} must be divisible by chunk {chunk}"
    nchunks = l // chunk

    def kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, state_ref):
        ci = pl.program_id(1)

        @pl.when(ci == 0)
        def _():
            state_ref[...] = jnp.zeros_like(state_ref)

        xq = x_ref[0].astype(jnp.float32)          # [Q, P]
        dtq = dt_ref[0].astype(jnp.float32)        # [Q]
        av = a_ref[0, 0].astype(jnp.float32)       # scalar
        bq = b_ref[0].astype(jnp.float32)          # [Q, N]
        cq = c_ref[0].astype(jnp.float32)          # [Q, N]

        aq = dtq * av                              # [Q], <= 0
        cums = jnp.cumsum(aq)                      # [Q]

        # inter-chunk: contribution of the carried state
        y_inter = jax.lax.dot_general(
            cq, state_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * jnp.exp(cums)[:, None]  # [Q, P]

        # intra-chunk: masked decay-weighted "attention"
        scores = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)  # [Q, Q]
        li = cums[:, None] - cums[None, :]
        ii = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        decay = jnp.where(ii >= jj, jnp.exp(li), 0.0)
        w = scores * decay * dtq[None, :]
        y_intra = jax.lax.dot_general(w, xq, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)

        y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)

        # state update: S' = exp(cums_Q) S + sum_j exp(cums_Q - cums_j) dt_j x_j b_j^T
        total = cums[-1]
        wgt = jnp.exp(total - cums) * dtq          # [Q]
        ds = jax.lax.dot_general(xq * wgt[:, None], bq, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [P, N]
        state_ref[...] = state_ref[...] * jnp.exp(total) + ds

        @pl.when(ci == nchunks - 1)
        def _():
            s_ref[0] = state_ref[...].astype(s_ref.dtype)

    y, s = pl.pallas_call(
        kernel,
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, 1), lambda i, ci: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, p, n), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, s
