"""Shared epilogue-chain spec for fused kernels.

An epilogue is a list of :class:`EpilogueOp` applied in order to the f32
accumulator tile while it is still in VMEM (the fusion stage's product).
``operand`` names an extra kernel input (bias/residual); ``value`` is a
compile-time scalar.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

UNARY = {
    "relu": lambda x: jnp.maximum(x, 0),
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "swish": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "mish": lambda x: x * jnp.tanh(jax.nn.softplus(x)),
    "exp": jnp.exp,
    "abs": jnp.abs,
    "square": jnp.square,
    "neg": jnp.negative,
    "softplus": jax.nn.softplus,
    "identity": lambda x: x,
}

BINARY = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "minimum": jnp.minimum,
    "maximum": jnp.maximum,
    "bias_add": jnp.add,
}

SCALAR = {
    "scale": lambda x, v: x * v,
    "add_scalar": lambda x, v: x + v,
    "clamp_min": lambda x, v: jnp.maximum(x, v),
    "clamp_max": lambda x, v: jnp.minimum(x, v),
}

# terminal reductions over the N (last) axis of the [M, N] tile
REDUCTIONS = ("sum", "max", "min", "mean")


@dataclasses.dataclass
class EpilogueOp:
    op: str
    operand: Optional[str] = None      # extra-input name (bias/residual)
    value: Optional[float] = None      # scalar constant
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def validate(self):
        if self.op in UNARY:
            return
        if self.op in BINARY:
            if self.operand is None and self.value is None:
                raise ValueError(f"binary epilogue {self.op} needs operand or value")
            return
        if self.op in SCALAR:
            if self.value is None:
                raise ValueError(f"scalar epilogue {self.op} needs value")
            return
        raise ValueError(f"unsupported epilogue op {self.op!r}")


def apply_epilogue(tile: jnp.ndarray, epilogue: List[EpilogueOp],
                   operands: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Apply the chain to a tile (works on full arrays in the oracle too)."""
    x = tile
    for e in epilogue:
        if e.op in UNARY:
            x = UNARY[e.op](x)
        elif e.op in SCALAR:
            x = SCALAR[e.op](x, jnp.asarray(e.value, x.dtype))
        elif e.op in BINARY:
            if e.operand is not None:
                other = operands[e.operand].astype(x.dtype)
            else:
                other = jnp.asarray(e.value, x.dtype)
            x = BINARY[e.op](x, other)
        else:
            raise ValueError(e.op)
    return x


def reduce_tile(x: jnp.ndarray, reduction: str, axis: int = -1,
                keepdims: bool = True) -> jnp.ndarray:
    if reduction == "sum":
        return jnp.sum(x, axis=axis, keepdims=keepdims)
    if reduction == "max":
        return jnp.max(x, axis=axis, keepdims=keepdims)
    if reduction == "min":
        return jnp.min(x, axis=axis, keepdims=keepdims)
    if reduction == "mean":  # caller rescales: tiles see partial counts
        return jnp.sum(x, axis=axis, keepdims=keepdims)
    raise ValueError(reduction)


def reduce_combine(acc: jnp.ndarray, update: jnp.ndarray, reduction: str) -> jnp.ndarray:
    if reduction in ("sum", "mean"):
        return acc + update
    if reduction == "max":
        return jnp.maximum(acc, update)
    if reduction == "min":
        return jnp.minimum(acc, update)
    raise ValueError(reduction)


def reduce_init(reduction: str) -> float:
    return {"sum": 0.0, "mean": 0.0, "max": -jnp.inf, "min": jnp.inf}[reduction]
