"""Fused matmul Pallas kernel (the workhorse of the L2 suite).

Two implementations, matching the pipeline's before/after axis:

* :func:`matmul_fused_naive` — "manual pointer arithmetic": whole-array refs,
  flat output grid, explicit ``pl.load``/``pl.ds`` tile indexing, serial K
  loop in the body. Mosaic gets no BlockSpecs, so nothing is pipelined. This
  is the Triton-without-``make_block_ptr`` analogue the block-pointer stage
  modernizes away.

* :func:`matmul_fused` — BlockSpec-tiled: swizzled flat grid over output
  tiles (GROUP_M traversal), K as an innermost ``arbitrary`` grid dim with a
  persistent f32 VMEM accumulator, fused epilogue chain applied in-register,
  optional terminal row-reduction that never materializes the [M, N] result.

Config knobs map 1:1 to :class:`repro.ir.schedule.PallasConfig`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import CompilerParams

from repro.kernels.epilogue import (EpilogueOp, apply_epilogue, reduce_combine,
                                    reduce_init, reduce_tile)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _normalize_operand(name: str, arr: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Normalize epilogue operands to 2D [1|M, 1|N] for block mapping."""
    if arr.ndim == 0:
        return arr.reshape(1, 1)
    if arr.ndim == 1:
        if arr.shape[0] == n:
            return arr.reshape(1, n)
        if arr.shape[0] == m:
            return arr.reshape(m, 1)
        raise ValueError(f"operand {name}: 1D shape {arr.shape} matches neither M nor N")
    if arr.ndim == 2:
        return arr
    raise ValueError(f"operand {name}: rank {arr.ndim} unsupported")


def _operand_spec(arr: jnp.ndarray, bm: int, bn: int, m_of, n_of):
    """BlockSpec for a normalized [1|M, 1|N] operand."""
    om = arr.shape[0] != 1
    on = arr.shape[1] != 1
    bshape = (bm if om else 1, bn if on else 1)

    def idx(*grid_ids):
        return (m_of(*grid_ids) if om else 0, n_of(*grid_ids) if on else 0)

    return pl.BlockSpec(bshape, idx)


def _swizzle(p, mt: int, nt: int, group_m: int):
    """GROUP_M grid traversal (Triton matmul-tutorial swizzle, TPU edition)."""
    if group_m <= 1:
        return p // nt, p % nt
    group_size = group_m * nt
    gid = p // group_size
    first_m = gid * group_m
    gsz = jnp.minimum(mt - first_m, group_m)
    m = first_m + (p % group_size) % gsz
    n = (p % group_size) // gsz
    return m, n


# ======================================================================
# BlockSpec (modernized) implementation
# ======================================================================

def matmul_fused(a: jnp.ndarray, b: jnp.ndarray, *,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 group_m: int = 1, num_stages: int = 2,
                 epilogue: Optional[List[EpilogueOp]] = None,
                 operands: Optional[Dict[str, jnp.ndarray]] = None,
                 reduction: Optional[str] = None,
                 acc_dtype=jnp.float32,
                 out_dtype=None,
                 dimension_semantics: Tuple[str, ...] = ("parallel", "arbitrary"),
                 interpret: bool = True) -> jnp.ndarray:
    """C = epilogue(A @ B) [optionally reduced over N]. A: [M,K], B: [K,N]."""
    epilogue = epilogue or []
    operands = operands or {}
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    mt, nt, kt = _cdiv(m, block_m), _cdiv(n, block_n), _cdiv(k, block_k)

    op_names = sorted({e.operand for e in epilogue if e.operand is not None})
    norm_ops = {s: _normalize_operand(s, operands[s], m, n) for s in op_names}

    if reduction is None:
        return _matmul_epilogue_swizzled(
            a, b, norm_ops, op_names, epilogue, m, n, k,
            block_m, block_n, block_k, group_m, mt, nt, kt,
            acc_dtype, out_dtype, num_stages, dimension_semantics, interpret)
    return _matmul_reduce(
        a, b, norm_ops, op_names, epilogue, reduction, m, n, k,
        block_m, block_n, block_k, mt, nt, kt, acc_dtype, out_dtype, interpret)


def _matmul_epilogue_swizzled(a, b, norm_ops, op_names, epilogue, m, n, k,
                              bm, bn, bk, group_m, mt, nt, kt,
                              acc_dtype, out_dtype, num_stages,
                              dimension_semantics, interpret):
    m_of = lambda p, kk: _swizzle(p, mt, nt, group_m)[0]
    n_of = lambda p, kk: _swizzle(p, mt, nt, group_m)[1]

    k_ragged = k % bk != 0

    def kernel(a_ref, b_ref, *rest):
        *op_refs, o_ref, acc_ref = rest
        kk = pl.program_id(1)

        @pl.when(kk == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a_tile, b_tile = a_ref[...], b_ref[...]
        if k_ragged:
            # partial contraction blocks must be explicitly zero-masked: the
            # pipeline pads loads, and padded *contraction* columns would
            # pollute real outputs (padded M/N rows are store-masked instead)
            kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
            a_tile = jnp.where(kpos < k, a_tile, 0)
            b_tile = jnp.where(kpos.reshape(bk, 1) < k, b_tile, 0)
        acc_ref[...] += jnp.dot(a_tile, b_tile,
                                preferred_element_type=acc_dtype)

        @pl.when(kk == kt - 1)
        def _():
            tile_ops = {s: r[...] for s, r in zip(op_names, op_refs)}
            tile = apply_epilogue(acc_ref[...], epilogue, tile_ops)
            o_ref[...] = tile.astype(o_ref.dtype)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda p, kk: (m_of(p, kk), kk)),
        pl.BlockSpec((bk, bn), lambda p, kk: (kk, n_of(p, kk))),
    ]
    in_specs += [_operand_spec(norm_ops[s], bm, bn, m_of, n_of) for s in op_names]

    return pl.pallas_call(
        kernel,
        grid=(mt * nt, kt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda p, kk: (m_of(p, kk), n_of(p, kk))),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=tuple(dimension_semantics)[:2] or ("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, *[norm_ops[s] for s in op_names])


def _matmul_reduce(a, b, norm_ops, op_names, epilogue, reduction, m, n, k,
                   bm, bn, bk, mt, nt, kt, acc_dtype, out_dtype, interpret):
    """Row-reduction epilogue: grid (mt, nt, kt); the [M, N] product is never
    materialized — per-n-tile partials fold into a [bm, 1] scratch."""
    m_of = lambda i, j, kk: i
    n_of = lambda i, j, kk: j

    k_ragged = k % bk != 0

    def kernel(a_ref, b_ref, *rest):
        *op_refs, o_ref, acc_ref, red_ref = rest
        j, kk = pl.program_id(1), pl.program_id(2)

        @pl.when(kk == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a_tile, b_tile = a_ref[...], b_ref[...]
        if k_ragged:
            kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
            a_tile = jnp.where(kpos < k, a_tile, 0)
            b_tile = jnp.where(kpos.reshape(bk, 1) < k, b_tile, 0)
        acc_ref[...] += jnp.dot(a_tile, b_tile,
                                preferred_element_type=acc_dtype)

        @pl.when(kk == kt - 1)
        def _():
            tile_ops = {s: r[...] for s, r in zip(op_names, op_refs)}
            tile = apply_epilogue(acc_ref[...], epilogue, tile_ops)
            # mask ragged N so padded columns don't pollute the reduction
            ncol = j * bn + jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
            neutral = jnp.asarray(reduce_init(reduction), tile.dtype)
            tile = jnp.where(ncol < n, tile, neutral)
            part = reduce_tile(tile, reduction, axis=-1, keepdims=True)

            @pl.when(j == 0)
            def _():
                red_ref[...] = part

            @pl.when(j > 0)
            def _():
                red_ref[...] = reduce_combine(red_ref[...], part, reduction)

            @pl.when(j == nt - 1)
            def _():
                res = red_ref[...]
                if reduction == "mean":
                    res = res / n
                o_ref[...] = res.astype(o_ref.dtype)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    in_specs += [_operand_spec(norm_ops[s], bm, bn, m_of, n_of) for s in op_names]

    out = pl.pallas_call(
        kernel,
        grid=(mt, nt, kt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype),
                        pltpu.VMEM((bm, 1), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b, *[norm_ops[s] for s in op_names])
    return out[:, 0]


# ======================================================================
# Naive (manual pointer arithmetic) implementation
# ======================================================================

def matmul_fused_naive(a: jnp.ndarray, b: jnp.ndarray, *,
                       block_m: int = 128, block_n: int = 128, block_k: int = 128,
                       epilogue: Optional[List[EpilogueOp]] = None,
                       operands: Optional[Dict[str, jnp.ndarray]] = None,
                       reduction: Optional[str] = None,
                       out_dtype=None,
                       interpret: bool = True) -> jnp.ndarray:
    """The 'unoptimized input kernel': flat grid, manual tile indexing via
    pl.load/pl.ds over whole-array refs, bf16-unsafe f32 accumulation in
    registers, no masking, no pipelining. Requires divisible shapes (the
    missing_boundary_check issue, on purpose)."""
    epilogue = epilogue or []
    operands = operands or {}
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"naive kernel has no boundary checks: shape ({m},{n},{k}) not "
            f"divisible by blocks ({block_m},{block_n},{block_k})")
    mt, nt, kt = m // block_m, n // block_n, k // block_k

    op_names = sorted({e.operand for e in epilogue if e.operand is not None})
    norm_ops = {s: _normalize_operand(s, operands[s], m, n) for s in op_names}

    def kernel(a_ref, b_ref, *rest):
        op_refs = rest[:len(op_names)]
        o_ref = rest[len(op_names)]
        p = pl.program_id(0)
        mi, ni = p // nt, p % nt
        row0, col0 = mi * block_m, ni * block_n

        def body(kk, acc):
            a_tile = pl.load(a_ref, (pl.ds(row0, block_m), pl.ds(kk * block_k, block_k)))
            b_tile = pl.load(b_ref, (pl.ds(kk * block_k, block_k), pl.ds(col0, block_n)))
            return acc + jnp.dot(a_tile, b_tile, preferred_element_type=jnp.float32)

        acc = jax.lax.fori_loop(0, kt, body,
                                jnp.zeros((block_m, block_n), jnp.float32))
        tile_ops = {}
        for s, r in zip(op_names, op_refs):
            arr = norm_ops[s]
            rsel = pl.ds(row0, block_m) if arr.shape[0] != 1 else pl.ds(0, 1)
            csel = pl.ds(col0, block_n) if arr.shape[1] != 1 else pl.ds(0, 1)
            tile_ops[s] = pl.load(r, (rsel, csel))
        tile = apply_epilogue(acc, epilogue, tile_ops)
        if reduction is None:
            pl.store(o_ref, (pl.ds(row0, block_m), pl.ds(col0, block_n)),
                     tile.astype(o_ref.dtype))
        else:
            part = reduce_tile(tile, reduction, axis=-1, keepdims=True)
            if reduction == "mean":
                part = part / n
            # every n-tile accumulates into the same column: serialized, racy
            # unless the grid is sequential — which on TPU it is (no swizzle).
            prev = pl.load(o_ref, (pl.ds(row0, block_m), pl.ds(0, 1)))
            init = jnp.asarray(reduce_init(reduction), jnp.float32)
            prev = jnp.where(ni == 0, jnp.full_like(prev, init.astype(prev.dtype)), prev)
            comb = reduce_combine(prev.astype(jnp.float32), part, reduction)
            pl.store(o_ref, (pl.ds(row0, block_m), pl.ds(0, 1)),
                     comb.astype(o_ref.dtype))

    out_shape = (m, n) if reduction is None else (m, 1)
    full = lambda arr: pl.BlockSpec(arr.shape, lambda p: (0,) * arr.ndim)
    in_specs = [full(a), full(b)] + [full(norm_ops[s]) for s in op_names]
    out = pl.pallas_call(
        kernel,
        grid=(mt * nt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_shape, lambda p: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(out_shape, out_dtype),
        interpret=interpret,
    )(a, b, *[norm_ops[s] for s in op_names])
    if reduction is not None:
        # mean already rescaled in-kernel
        return out[:, 0]
    return out
