"""Analytic v5e roofline model for the attention kernels (the performance
signal for the Flash-Attention experiment, paper Table 3 / Fig. 14-15).

Same modeling discipline as ir/cost.py: every term derives from decisions the
kernel actually makes (tile sizes, dtype, online vs materialized softmax,
pipelining), evaluated against v5e constants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.hw.query import HardwareQuery
from repro.hw.specs import TPUSpec, TPU_V5E, dtype_itemsize


@dataclasses.dataclass
class AttentionCost:
    t_compute: float
    t_memory: float
    t_total: float
    flops: float
    hbm_bytes: float
    tflops: float
    bound: str

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)


def attention_flops(b: int, a: int, sq: int, skv: int, d: int) -> float:
    """QK^T + PV matmuls (2x2 flops/MAC) + softmax vector work."""
    return 4.0 * b * a * sq * skv * d + 6.0 * b * a * sq * skv


def naive_attention_cost(b: int, a: int, s: int, d: int,
                         spec: TPUSpec = TPU_V5E, block_q: int = 128,
                         dtype: str = "float32") -> AttentionCost:
    """The unoptimized kernel: per q-tile it loads the FULL K/V, materializes
    the whole score row, single-pass softmax, no pipelining, f32."""
    isz = dtype_itemsize(dtype)
    flops = attention_flops(b, a, s, s, d)
    qt = max(1, s // block_q)
    kv_traffic = b * a * qt * 2 * s * d * isz           # full K,V per q tile
    qo_traffic = b * a * s * d * isz * 2
    # scores spill: bq x S f32 row; fits VMEM only for short S
    scores_bytes = block_q * s * 4
    # a quarter of VMEM is realistically available to the naive kernel's
    # working set (no double-buffer discipline, f32 everywhere)
    budget = spec.vmem_bytes // 4
    spill = scores_bytes + 2 * s * d * isz > budget
    score_traffic = 2.0 * b * a * s * s * 4 if spill else 0.0
    traffic = kv_traffic + qo_traffic + score_traffic
    util = 0.55
    t_comp = flops / (spec.peak_flops(dtype) * util)
    if spill:
        # score spills serialize the pipeline: copies can't overlap compute
        t_mem = traffic / (spec.hbm_bw * 0.5)
        t = t_comp + t_mem
    else:
        # short contexts fit VMEM; even the naive kernel gets overlap
        t_mem = traffic / (spec.hbm_bw * 0.7)
        t = max(t_comp, t_mem)
    return AttentionCost(t_comp, t_mem, t, flops, traffic,
                         flops / t / 1e12,
                         "memory" if t_mem > t_comp else "compute")


def flash_attention_cost(b: int, a: int, s: int, d: int,
                         spec: TPUSpec = TPU_V5E,
                         block_q: Optional[int] = None,
                         block_kv: Optional[int] = None,
                         dtype: str = "bfloat16") -> AttentionCost:
    """The optimized kernel: online softmax (no score materialization),
    shape-aware tiles from the hardware query, bf16 io / f32 accumulation,
    double-buffered copies overlapping the MXU."""
    hw = HardwareQuery(spec)
    p = hw.get_attention_params(s, s, d, dtype)
    bq = block_q or p.block_m
    isz = dtype_itemsize(dtype)
    flops = attention_flops(b, a, s, s, d)
    qt = max(1, -(-s // bq))
    kv_traffic = b * a * qt * 2 * s * d * isz           # K,V re-read per q tile
    qo_traffic = b * a * s * d * isz * 2
    traffic = kv_traffic + qo_traffic
    t_mem = traffic / (spec.hbm_bw * 0.85)
    util = 0.85 if d >= 128 else max(0.4, 0.85 * d / 128)
    t_comp = flops / (spec.peak_flops(dtype) * util)
    t = max(t_comp, t_mem) + spec.launch_overhead_s
    return AttentionCost(t_comp, t_mem, t, flops, traffic,
                         flops / t / 1e12,
                         "memory" if t_mem > t_comp else "compute")


def naive_oom(b: int, a: int, s: int, d: int, spec: TPUSpec = TPU_V5E,
              dtype: str = "float32") -> bool:
    """Full S x S score materialization in HBM (the eager path): does one
    head's score matrix even fit? (paper §VI-E-d: S=32k is a correctness
    requirement, not just performance)."""
    per_head_scores = s * s * dtype_itemsize(dtype)
    return per_head_scores * a > spec.hbm_bytes // 2
