"""jit'd public wrappers for the Pallas kernels + the tuned-config registry.

Models call these entry points; each consults :class:`TunedRegistry` — the
output artifact of the Forge pipeline (§DESIGN 3.1) — for the kernel config
matching the call-site signature, falling back to the hardware query system's
shape-aware defaults. ``use_pallas=False`` routes to the jnp oracle (the path
the multi-pod dry-run lowers, since this container compiles for CPU).
"""

from __future__ import annotations

import functools
import json
import os
import pathlib
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.hw.query import HardwareQuery
from repro.hw.specs import TPU_V5E
from repro.kernels import ref as ref_ops
from repro.kernels.epilogue import EpilogueOp
from repro.kernels.matmul_fused import matmul_fused
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.elementwise import elementwise_chain
from repro.kernels.ssd_scan import ssd_scan

_QUERY = HardwareQuery(TPU_V5E)


class TunedRegistry:
    """Persisted kernel configs keyed by (kernel, signature)."""

    def __init__(self, path: Optional[pathlib.Path] = None):
        default = pathlib.Path(__file__).resolve().parents[1] / "configs" / "tuned" / "kernels.json"
        self.path = pathlib.Path(os.environ.get("REPRO_TUNED_KERNELS", default))
        self._cache: Optional[Dict] = None

    def _load(self) -> Dict:
        if self._cache is None:
            if self.path.exists():
                self._cache = json.loads(self.path.read_text())
            else:
                self._cache = {}
        return self._cache

    def get(self, kernel: str, signature: str) -> Optional[Dict]:
        return self._load().get(kernel, {}).get(signature)

    def put(self, kernel: str, signature: str, config: Dict):
        data = self._load()
        data.setdefault(kernel, {})[signature] = config
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(data, indent=2, sort_keys=True))
        tmp.replace(self.path)


REGISTRY = TunedRegistry()


def _sig(*parts) -> str:
    return "/".join(str(p) for p in parts)


# ----------------------------------------------------------------------
def fused_matmul(a, b, epilogue: Optional[List[EpilogueOp]] = None,
                 operands=None, reduction=None, *,
                 use_pallas: bool = True, interpret: bool = True,
                 config: Optional[Dict] = None):
    if not use_pallas:
        return ref_ops.matmul_fused_ref(a, b, epilogue, operands,
                                        reduction=reduction)
    m, k = a.shape
    n = b.shape[1]
    cfg = config or REGISTRY.get("matmul_fused", _sig(m, n, k, a.dtype)) or {}
    if not cfg:
        p = _QUERY.get_optimal_params(m, n, k, str(a.dtype))
        cfg = {"block_m": p.block_m, "block_n": p.block_n, "block_k": p.block_k,
               "group_m": p.group_m, "num_stages": p.num_stages}
    return matmul_fused(a, b,
                        block_m=min(cfg.get("block_m", 128), m),
                        block_n=min(cfg.get("block_n", 128), n),
                        block_k=min(cfg.get("block_k", 128), k),
                        group_m=cfg.get("group_m", 1),
                        num_stages=cfg.get("num_stages", 2),
                        epilogue=epilogue, operands=operands,
                        reduction=reduction, interpret=interpret)


def attention(q, k, v, *, causal=False, window=None,
              use_pallas: bool = True, interpret: bool = True,
              config: Optional[Dict] = None):
    if not use_pallas:
        return ref_ops.attention_ref(q, k, v, causal=causal, window=window)
    sq, skv, d = q.shape[-2], k.shape[-2], q.shape[-1]
    cfg = config or REGISTRY.get("flash_attention", _sig(sq, skv, d, q.dtype)) or {}
    if not cfg:
        p = _QUERY.get_attention_params(sq, skv, d, str(q.dtype))
        cfg = {"block_q": p.block_m, "block_kv": p.block_n}
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=cfg.get("block_q", 128),
                           block_kv=cfg.get("block_kv", 128),
                           interpret=interpret)


def decode_attn(q, k, v, *, lengths=None, use_pallas: bool = True,
                interpret: bool = True, config: Optional[Dict] = None):
    if not use_pallas:
        return ref_ops.decode_attention_ref(q, k, v, lengths=lengths)
    s = k.shape[-2]
    cfg = config or REGISTRY.get("decode_attention", _sig(s, q.shape[-1], q.dtype)) or {}
    return decode_attention(q, k, v, lengths=lengths,
                            block_kv=cfg.get("block_kv", min(512, s)),
                            interpret=interpret)


def rms_norm(x, w, *, eps=1e-6, use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return ref_ops.rmsnorm_ref(x, w, eps=eps)
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = rmsnorm(flat, w, eps=eps, interpret=interpret)
    return out.reshape(*lead, d)


def fused_elementwise(x, epilogue: List[EpilogueOp], operands=None, *,
                      use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return ref_ops.elementwise_chain_ref(x, epilogue, operands)
    lead = x.shape[:-1]
    c = x.shape[-1]
    flat = x.reshape(-1, c)
    out = elementwise_chain(flat, epilogue, operands=operands, interpret=interpret)
    return out.reshape(*lead, c)


def ssd(x, dt, a, b, c, *, chunk=128, use_pallas: bool = True,
        interpret: bool = True):
    """x: [B, L, H, P], dt: [B, L, H], a: [H], b/c: [B, L, N]."""
    if not use_pallas:
        l = x.shape[1]
        if l % min(chunk, l) == 0 and l > 1:
            # chunked jnp path: same decomposition as the Pallas kernel,
            # O(L/chunk) backward state (the sequential ref is O(L))
            return ref_ops.ssd_chunked_ref(x, dt, a, b, c,
                                           chunk=min(chunk, l))
        return ref_ops.ssd_ref(x, dt, a, b, c)
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    xf = jnp.transpose(x, (0, 2, 1, 3)).reshape(bsz * h, l, p)
    dtf = jnp.transpose(dt, (0, 2, 1)).reshape(bsz * h, l)
    af = jnp.broadcast_to(a[None, :], (bsz, h)).reshape(bsz * h, 1)
    bf = jnp.broadcast_to(b[:, None], (bsz, h, l, n)).reshape(bsz * h, l, n)
    cf = jnp.broadcast_to(c[:, None], (bsz, h, l, n)).reshape(bsz * h, l, n)
    y, s = ssd_scan(xf, dtf, af, bf, cf, chunk=min(chunk, l), interpret=interpret)
    y = y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
    s = s.reshape(bsz, h, p, n)
    return y, s
