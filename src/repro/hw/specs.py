"""TPU hardware specifications.

The paper's GPU hardware query system (Xe-Forge IV-E) reads device properties at
runtime (EU count, SLM capacity, GRF modes, ...). On TPU there is no runtime to
query in this container, so the spec table *is* the detection path: ``get_spec``
maps a generation name to a :class:`TPUSpec`, exactly the role of
``torch.xpu.get_device_properties`` + family defaults in the paper.

All constants are per-chip (one TensorCore exposed per v5e chip). The roofline
constants used by the assignment are the v5e ones: 197 bf16 TFLOP/s, 819 GB/s
HBM, ~50 GB/s per ICI link.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Per-chip TPU hardware description (planning model, not a simulator)."""

    name: str
    # Compute.
    peak_flops_bf16: float  # FLOP/s with bf16 inputs / f32 accumulation (MXU)
    peak_flops_f32: float   # FLOP/s at f32 (MXU passes / VPU)
    mxu_shape: Tuple[int, int] = (128, 128)  # systolic array tile
    vpu_lanes: int = 128     # vector lane width (last dim)
    sublanes: int = 8        # second-to-last dim tile at f32
    # Memory hierarchy.
    hbm_bytes: int = 16 * 2**30
    hbm_bw: float = 819e9            # bytes/s
    vmem_bytes: int = 64 * 2**20     # usable VMEM planning budget (assumption; cf. DESIGN.md)
    vmem_bw: float = 20e12           # effective VMEM bandwidth (order-of-magnitude planning figure)
    smem_bytes: int = 1 * 2**20      # scalar memory (SMEM) budget for scalar prefetch args
    # Interconnect.
    ici_link_bw: float = 50e9        # bytes/s per link (assignment constant)
    ici_links: int = 4               # 2D torus on v5e: 4 links/chip
    # Misc planning knobs.
    launch_overhead_s: float = 2e-6  # fixed per-kernel launch/pipeline-fill overhead

    # ---- derived helpers -------------------------------------------------
    def peak_flops(self, dtype: str) -> float:
        if dtype in ("bf16", "bfloat16", "f16", "float16", "fp16"):
            return self.peak_flops_bf16
        if dtype in ("int8", "i8", "fp8"):
            # v5e int8: 394 TOPS (2x bf16)
            return self.peak_flops_bf16 * 2
        if dtype in ("float64", "f64"):
            # no native f64: XLA software emulation
            return self.peak_flops_f32 / 8
        return self.peak_flops_f32

    def min_tile(self, dtype: str) -> Tuple[int, int]:
        """Native (sublane, lane) tile for a dtype: (8,128) f32, (16,128) bf16, (32,128) int8."""
        itemsize = dtype_itemsize(dtype)
        packing = max(1, 4 // itemsize)
        return (self.sublanes * packing, self.vpu_lanes)


def dtype_itemsize(dtype: str) -> int:
    d = str(dtype)
    if d in ("float64", "f64", "int64", "i64"):
        return 8
    if d in ("float32", "f32", "int32", "i32", "uint32"):
        return 4
    if d in ("bfloat16", "bf16", "float16", "f16", "fp16", "int16"):
        return 2
    if d in ("int8", "i8", "uint8", "fp8", "float8_e4m3fn", "float8_e5m2"):
        return 1
    raise ValueError(f"unknown dtype {dtype!r}")


# TPU v5e ("the assignment target"): 197 TFLOP/s bf16, 819 GB/s HBM, 16 GB.
TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=197e12 / 4,  # f32 matmul runs the MXU at ~1/4 rate
)

# TPU v4 for comparison experiments.
TPU_V4 = TPUSpec(
    name="tpu_v4",
    peak_flops_bf16=275e12,
    peak_flops_f32=275e12 / 4,
    hbm_bytes=32 * 2**30,
    hbm_bw=1228e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=6,  # 3D torus
)

# TPU v5p.
TPU_V5P = TPUSpec(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    peak_flops_f32=459e12 / 4,
    hbm_bytes=95 * 2**30,
    hbm_bw=2765e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=100e9,
    ici_links=6,
)

_SPECS: Dict[str, TPUSpec] = {s.name: s for s in (TPU_V5E, TPU_V4, TPU_V5P)}
_SPECS.update({"v5e": TPU_V5E, "v4": TPU_V4, "v5p": TPU_V5P})


def get_spec(name: str = "tpu_v5e") -> TPUSpec:
    """Hardware 'detection': resolve a generation name to its spec."""
    try:
        return _SPECS[name]
    except KeyError:
        raise KeyError(f"unknown TPU generation {name!r}; known: {sorted(_SPECS)}")
