from repro.hw.specs import TPUSpec, TPU_V5E, TPU_V4, TPU_V5P, get_spec
from repro.hw.query import HardwareQuery, OptimalParams

__all__ = [
    "TPUSpec",
    "TPU_V5E",
    "TPU_V4",
    "TPU_V5P",
    "get_spec",
    "HardwareQuery",
    "OptimalParams",
]
