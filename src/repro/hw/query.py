"""Shape-aware hardware query system (paper §IV-E, adapted to TPU).

``HardwareQuery.get_optimal_params(M, N, K, dtype)`` reproduces the paper's
``get_optimal_params()``: tile sizes clamped to the nearest power of two not
exceeding the dimension, asymmetric tiles for skinny matrices, BLOCK_K reduced
until the working set fits the register-file/VMEM budget, and a GROUP_M
(tile-swizzling) factor derived from the tile count relative to compute units.

TPU translation of the Intel knobs:
  - GRF large/small mode  -> VMEM working-set budget (the block-size/pipeline
    depth trade Mosaic makes); exposed as ``vmem_budget_frac``.
  - num_warps             -> nothing to set per-kernel on TPU (Mosaic schedules
    the VPU); the occupancy lever is the grid size, reported as ``grid_hint``.
  - GROUP_SIZE_M swizzle  -> identical concept: grid traversal reordering for
    HBM/L2-analog locality. Same guard as the paper: only when >1 M-tile.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.hw.specs import TPUSpec, TPU_V5E, dtype_itemsize


def _pow2_floor(x: int) -> int:
    if x <= 0:
        return 1
    return 1 << (int(x).bit_length() - 1)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class OptimalParams:
    """Shape-aware kernel parameters (the analogue of the paper's dict)."""

    block_m: int
    block_n: int
    block_k: int
    group_m: int                  # grid swizzle factor (GROUP_SIZE_M analogue)
    num_stages: int               # HBM->VMEM pipeline depth hint
    dimension_semantics: Tuple[str, ...]
    vmem_budget_frac: float       # fraction of VMEM the working set may claim
    acc_dtype: str = "float32"
    grid_hint: Optional[Tuple[int, ...]] = None

    def working_set_bytes(self, itemsize: int, acc_itemsize: int = 4) -> int:
        """(Mblk x Kblk + Kblk x Nblk) inputs + (Mblk x Nblk) f32 accumulator,
        times the pipeline depth for the streamed operands."""
        stream = (self.block_m * self.block_k + self.block_k * self.block_n) * itemsize
        acc = self.block_m * self.block_n * acc_itemsize
        return stream * max(1, self.num_stages) + acc


class HardwareQuery:
    """Runtime 'device query' + shape-aware parameter derivation."""

    def __init__(self, spec: TPUSpec = TPU_V5E):
        self.spec = spec

    # ------------------------------------------------------------------
    def describe(self) -> dict:
        s = self.spec
        return {
            "name": s.name,
            "peak_flops_bf16": s.peak_flops_bf16,
            "hbm_bytes": s.hbm_bytes,
            "hbm_bw": s.hbm_bw,
            "vmem_bytes": s.vmem_bytes,
            "mxu_shape": s.mxu_shape,
            "min_tile_f32": s.min_tile("float32"),
            "min_tile_bf16": s.min_tile("bfloat16"),
            "ici_link_bw": s.ici_link_bw,
            "ici_links": s.ici_links,
        }

    # ------------------------------------------------------------------
    def get_optimal_params(
        self,
        m: int,
        n: int,
        k: int,
        dtype: str = "bfloat16",
        *,
        vmem_budget_frac: float = 0.5,
        fused_epilogue_operands: int = 0,
    ) -> OptimalParams:
        """Derive matmul-family tile parameters for an (M, N, K) problem.

        Mirrors the paper's logic 1:1, with TPU-native alignment:
          1. start from arch defaults (512x512 bf16 tiles target the MXU),
          2. clamp each block to pow2_floor(dim) (no padded-thread waste),
          3. asymmetric tiles for skinny shapes,
          4. shrink BLOCK_K (then N, then M) until the VMEM budget holds,
          5. GROUP_M from tile count vs. compute units (guard: >1 M-tile).
        """
        spec = self.spec
        itemsize = dtype_itemsize(dtype)
        sub, lane = spec.min_tile(dtype)

        # 1. architecture defaults.
        block_m, block_n, block_k = 512, 512, 512

        # 2. clamp to problem dims (power-of-two floor, but at least the
        #    native tile so we never emit sub-(8,128) blocks).
        block_m = max(min(block_m, _pow2_floor(m)), min(sub, _round_up(m, sub)))
        block_n = max(min(block_n, _pow2_floor(n)), min(lane, _round_up(n, lane)))
        block_k = max(min(block_k, _pow2_floor(k)), min(lane, _round_up(k, lane)))

        # 3. skinny-matrix asymmetry (paper: bigger BLOCK_M for tall-skinny,
        #    bigger BLOCK_N for short-wide).
        if m >= 4 * n and block_m < 1024:
            block_m = min(_pow2_floor(m), 1024)
        if n >= 4 * m and block_n < 1024:
            block_n = min(_pow2_floor(n), 1024)

        # 4. VMEM budget fitting: shrink K first (it only affects pipeline
        #    granularity), then N, then M. Epilogue operands (bias, residual)
        #    stream alongside the output tile.
        num_stages = 2
        budget = int(spec.vmem_bytes * vmem_budget_frac)

        def ws(bm: int, bn: int, bk: int) -> int:
            stream = (bm * bk + bk * bn) * itemsize * num_stages
            acc = bm * bn * 4
            epi = fused_epilogue_operands * bm * bn * itemsize
            return stream + acc + epi

        while ws(block_m, block_n, block_k) > budget and block_k > lane:
            block_k //= 2
        while ws(block_m, block_n, block_k) > budget and block_n > lane:
            block_n //= 2
        while ws(block_m, block_n, block_k) > budget and block_m > sub:
            block_m //= 2

        # 5. grid swizzle factor.
        m_tiles = max(1, -(-m // block_m))
        n_tiles = max(1, -(-n // block_n))
        total_tiles = m_tiles * n_tiles
        if m_tiles <= 1 or total_tiles < 16:
            group_m = 1  # paper guard: swizzling needs >1 M-tile / enough tiles
        else:
            # target ~4 tile-groups in flight per core-equivalent.
            group_m = max(1, min(m_tiles, _pow2_floor(max(1, total_tiles // 4))))
            group_m = min(group_m, 8)

        # deeper pipelining pays off for long K reductions.
        if k // max(block_k, 1) >= 8:
            num_stages = 3

        return OptimalParams(
            block_m=int(block_m),
            block_n=int(block_n),
            block_k=int(block_k),
            group_m=int(group_m),
            num_stages=num_stages,
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            vmem_budget_frac=vmem_budget_frac,
            grid_hint=(m_tiles, n_tiles, max(1, -(-k // block_k))),
        )

    # ------------------------------------------------------------------
    def get_attention_params(
        self,
        seq_q: int,
        seq_kv: int,
        head_dim: int,
        dtype: str = "bfloat16",
        *,
        vmem_budget_frac: float = 0.5,
    ) -> OptimalParams:
        """Flash-attention tile parameters: block_m = query tile, block_n = KV tile,
        block_k = head_dim (never split)."""
        spec = self.spec
        itemsize = dtype_itemsize(dtype)
        sub, lane = spec.min_tile(dtype)
        d = _round_up(head_dim, lane)

        block_q = min(_pow2_floor(seq_q), 512)
        block_kv = min(_pow2_floor(seq_kv), 1024)
        block_q = max(block_q, sub)
        block_kv = max(block_kv, lane)

        budget = int(spec.vmem_bytes * vmem_budget_frac)

        def ws(bq: int, bkv: int) -> int:
            qkv = (bq * d + 2 * bkv * d) * itemsize * 2  # double-buffered
            scores = bq * bkv * 4
            acc = bq * d * 4 + 2 * bq * 4  # o accumulator + m/l carries
            return qkv + scores + acc

        while ws(block_q, block_kv) > budget and block_kv > lane:
            block_kv //= 2
        while ws(block_q, block_kv) > budget and block_q > sub:
            block_q //= 2

        return OptimalParams(
            block_m=int(block_q),
            block_n=int(block_kv),
            block_k=int(d),
            group_m=1,
            num_stages=2,
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            vmem_budget_frac=vmem_budget_frac,
        )

    # ------------------------------------------------------------------
    def autotune_grid(
        self, m: int, n: int, k: int, dtype: str = "bfloat16", max_configs: int = 12
    ):
        """Curated autotune configurations (paper stage 10): up to ``max_configs``
        architecturally valid configs ordered by expected performance."""
        base = self.get_optimal_params(m, n, k, dtype)
        seen = set()
        out = []

        def push(p: OptimalParams):
            key = (p.block_m, p.block_n, p.block_k, p.group_m, p.num_stages)
            if key in seen:
                return
            itemsize = dtype_itemsize(dtype)
            if p.working_set_bytes(itemsize) > self.spec.vmem_bytes:
                return  # architecturally invalid: would not fit VMEM
            sub, lane = self.spec.min_tile(dtype)
            if p.block_m % sub or p.block_n % lane or p.block_k % lane:
                if p.block_m < sub or p.block_n < lane or p.block_k < lane:
                    return
            seen.add(key)
            out.append(p)

        push(base)
        for fm in (2, 1, 0.5):
            for fn in (2, 1, 0.5):
                for fk in (1, 0.5, 2):
                    p = dataclasses.replace(
                        base,
                        block_m=max(8, int(base.block_m * fm)),
                        block_n=max(128, int(base.block_n * fn)),
                        block_k=max(128, int(base.block_k * fk)),
                    )
                    push(p)
                    if len(out) >= max_configs:
                        return out
        for g in (1, 4, 8):
            push(dataclasses.replace(base, group_m=g))
            if len(out) >= max_configs:
                break
        return out[:max_configs]
