"""Version-compat shims for the pinned container toolchain.

``jax.shard_map`` only exists on newer jax; the image pins jax 0.4.x where the
API lives at ``jax.experimental.shard_map.shard_map`` and the replication-check
kwarg is ``check_rep`` instead of ``check_vma``. Call sites import from here so
they stay written against the modern surface.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as _pltpu

# jax 0.4.x names the Mosaic param struct TPUCompilerParams; newer jax renamed
# it to CompilerParams. Kernels import the symbol from here.
CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
