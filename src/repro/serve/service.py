"""ForgeService: the multi-tenant hosted optimization backend.

The engine already speaks every protocol a service needs — a JSON-safe wire
codec (:mod:`repro.core.job_codec`), typed :class:`OptimizationReport`\\ s,
per-job stage observers, and in-flight coalescing — but it only runs as a
script. This module is the long-running layer on top: one
:class:`ForgeService` owns one :class:`~repro.core.forge.Forge` and turns
submissions from many clients into engine batches:

* **Priority async job queue** — submissions land in a heap ordered by
  (priority desc, arrival seq asc); a single dispatcher thread drains it
  into ``optimize_batch`` waves of up to ``ServiceConfig.wave_size`` jobs.
  Parallelism *inside* a wave belongs to the engine (``ForgeConfig.workers``
  + execution backend); keeping one dispatcher keeps every determinism
  guarantee the engine makes (priors frozen per batch, leader/follower
  transfer phases) intact for service traffic too.

* **Cross-request dedup by exact cache key** — the engine's ``_inflight``
  coalescing only spans one batch; the service extends it to service
  lifetime. A submission whose exact store key matches a queued/running job
  *attaches* to it: no second engine run, live stage events mirrored as
  they happen, and an identical per-job report on completion. (A resubmit
  *after* completion goes to the engine and replays from the shared store —
  that path is already cheap and keeps reports fresh.)

* **Per-client token-bucket rate limiting** — clients are identified by API
  token (the HTTP layer reads ``X-API-Key`` / ``Authorization: Bearer``);
  each token gets a private bucket (``rate_per_sec``, ``burst``) and an
  over-budget submit raises :class:`RateLimited` (HTTP 429) with a
  retry-after hint.

* **Shared multi-tenant ResultStore** — all clients optimize through one
  Forge, so one client's verified optimization warms every later request:
  an exact resubmit replays, a family neighbor transfers. ``stats()``
  surfaces the store/engine/verify counters so the warming is observable.

* **Per-job event fan-out** — every job buffers its stage records (a
  batch-scoped :class:`~repro.core.observers.ForgeObserver` threaded
  through ``Forge.optimize_batch`` carries the submission index in each
  :class:`StageEvent`, so two in-flight jobs with the same kernel name
  can't cross streams). SSE readers replay the buffer, then follow live.

Everything is stdlib; the HTTP layer lives in :mod:`repro.serve.http`.
"""

from __future__ import annotations

import copy
import dataclasses
import heapq
import threading
import time
import traceback
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core import job_codec
from repro.core import journal as journal_mod
from repro.core.config import ForgeConfig
from repro.core.engine import KernelJob, compute_job_keys
from repro.core.faults import FaultPlan, InjectedCrash
from repro.core.forge import Forge, OptimizationReport
from repro.core.observers import ForgeObserver, StageEvent

__all__ = ["ForgeService", "ServiceConfig", "ServiceJob", "JOB_STATES",
           "RateLimited", "ServiceClosed", "QueueFull", "UnknownJob",
           "DEFAULT_CLIENT"]

#: job lifecycle: queued -> running -> done | failed; queued -> cancelled
JOB_STATES = ("queued", "running", "done", "failed", "cancelled")
_TERMINAL = ("done", "failed", "cancelled")

DEFAULT_CLIENT = "anonymous"


class RateLimited(Exception):
    """A client exhausted its token bucket; retry after ``retry_after_s``."""

    def __init__(self, client: str, retry_after_s: float):
        self.client = client
        self.retry_after_s = retry_after_s
        super().__init__(
            f"client {client!r} is rate-limited; retry in "
            f"{retry_after_s:.2f}s")


class ServiceClosed(Exception):
    """Submission rejected: the service is draining or shut down."""


class QueueFull(Exception):
    """Submission rejected: the queue is at ``max_queue_depth``."""


class UnknownJob(KeyError):
    """No job with the requested id exists on this service."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service-level knobs (the optimization policy itself lives in
    :class:`ForgeConfig` — this object only shapes *how requests queue*)."""

    wave_size: int = 4              # max jobs per optimize_batch wave
    max_queue_depth: int = 1024     # queued (non-attached) jobs; 0 = unbounded
    rate_per_sec: float = 0.0       # per-client token refill; 0 disables
    burst: int = 8                  # per-client bucket capacity
    default_priority: int = 0       # higher drains first; FIFO within a level

    def __post_init__(self):
        if self.wave_size < 1:
            raise ValueError("wave_size must be >= 1")
        if self.max_queue_depth < 0:
            raise ValueError("max_queue_depth must be >= 0 (0 = unbounded)")
        if self.rate_per_sec < 0:
            raise ValueError("rate_per_sec must be >= 0 (0 disables)")
        if self.burst < 1:
            raise ValueError("burst must be >= 1")


class _TokenBucket:
    """Classic token bucket; one per client token. Self-locking so refill
    arithmetic never races between HTTP handler threads."""

    def __init__(self, rate_per_sec: float, burst: int):
        self.rate = float(rate_per_sec)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t = time.monotonic()
        self._lock = threading.Lock()

    def try_acquire(self) -> Tuple[bool, float]:
        """Take one token. Returns ``(ok, retry_after_s)``."""
        with self._lock:
            now = time.monotonic()
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t) * self.rate)
            self._t = now
            if self.tokens >= 1.0:
                self.tokens -= 1.0
                return True, 0.0
            return False, (1.0 - self.tokens) / self.rate


class ServiceJob:
    """One submission's service-side record. All mutable fields are guarded
    by the service's single condition variable."""

    def __init__(self, job_id: str, job: KernelJob, client: str,
                 priority: int, exact_key: str,
                 attached_to: Optional[str] = None, seq: int = 0):
        self.id = job_id
        self.job = job
        self.client = client
        self.priority = priority
        self.exact_key = exact_key
        self.attached_to = attached_to      # primary job id when deduped
        self.seq = seq                      # arrival order (journal replay)
        self.state = "queued"
        # wall-clock fields are display timestamps only; every *duration*
        # is computed from the monotonic anchors below, so an NTP step
        # can't skew reported wait/run times
        self.created_s = time.time()
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self._created_m = time.monotonic()
        self._started_m: Optional[float] = None
        self._finished_m: Optional[float] = None
        self.events: List[Dict[str, Any]] = []   # stage records, in order
        self.report: Optional[Dict[str, Any]] = None
        self.error: Optional[str] = None

    def status_dict(self, queue_position: Optional[int] = None
                    ) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "id": self.id,
            "name": self.job.name,
            "client": self.client,
            "state": self.state,
            "priority": self.priority,
            "deduped": self.attached_to is not None,
            "attached_to": self.attached_to,
            "created_s": self.created_s,
            "started_s": self.started_s,
            "finished_s": self.finished_s,
            # monotonic-derived durations (None until the anchor exists;
            # jobs restored from a journal have no live anchors)
            "wait_s": (self._started_m - self._created_m
                       if self._started_m is not None else None),
            "run_s": (self._finished_m - self._started_m
                      if self._started_m is not None
                      and self._finished_m is not None else None),
            "events": len(self.events),
        }
        if queue_position is not None:
            d["queue_position"] = queue_position
        if self.error is not None:
            d["error"] = self.error
        if self.report is not None:
            d["report"] = self.report
        return d


class _WaveObserver(ForgeObserver):
    """Batch-scoped observer for one dispatcher wave: mirrors every stage
    record into the owning job's event buffer (and the buffers of all
    attached jobs) keyed by ``StageEvent.index`` — the submission index,
    so two in-flight jobs with the same kernel name can't cross streams."""

    def __init__(self, service: "ForgeService", wave: List["ServiceJob"]):
        self._service = service
        self._wave = wave

    def on_stage(self, event: StageEvent) -> None:
        if event.index is None:
            return
        svc, primary = self._service, self._wave[event.index]
        rec = dataclasses.asdict(event.record)
        with svc._cv:
            sinks = [primary]
            sinks += [svc._jobs[a] for a in svc._attached.get(primary.id, ())]
            for sink in sinks:
                sink.events.append(dict(rec))
            svc._cv.notify_all()


class ForgeService:
    """The hosted optimization backend: one Forge, many clients.

    ``start()`` launches the dispatcher thread (``autostart=True`` does it
    from the constructor); ``shutdown(drain=True)`` stops intake, drains
    the queue, and closes the Forge. Thread-safe throughout: submissions
    arrive from HTTP handler threads, events fan out from engine worker
    threads, SSE readers block on the same condition variable.
    """

    def __init__(self, config: Optional[ForgeConfig] = None, *,
                 forge: Optional[Forge] = None,
                 service_config: Optional[ServiceConfig] = None,
                 autostart: bool = True,
                 journal_path: Optional[str] = None,
                 fault_plan: Optional[FaultPlan] = None):
        self.forge = forge if forge is not None else Forge(config
                                                           or ForgeConfig())
        self.service_config = service_config or ServiceConfig()
        # ONE lock+condition guards every piece of mutable service state
        # (job records, queue heap, dedup map, counters). Fan-out and SSE
        # wake-ups share it too — no lock ordering to get wrong, and at
        # service scale (handfuls of in-flight jobs) contention is noise.
        self._cv = threading.Condition()
        self._jobs: Dict[str, ServiceJob] = {}
        self._order: List[str] = []               # submission order (stats)
        self._heap: List[Tuple[int, int, str]] = []   # (-prio, seq, job_id)
        self._seq = 0
        self._inflight_keys: Dict[str, str] = {}  # exact key -> primary id
        self._attached: Dict[str, List[str]] = {}  # primary id -> attached
        self._buckets: Dict[str, _TokenBucket] = {}
        self._clients: Dict[str, Dict[str, int]] = {}
        self._accepting = True
        self._stopping = False
        self._started_s = time.time()          # display timestamp
        self._started_m = time.monotonic()     # uptime anchor
        self._dispatcher: Optional[threading.Thread] = None
        self._fault_plan = fault_plan
        #: set when an injected dispatcher crash halted the drain loop —
        #: the service is then "dead" the way a crashed process is, and
        #: the journal is the only live copy of its state
        self.dispatcher_crashed = False
        self._journal: Optional[journal_mod.Journal] = None
        self._recovered_jobs = 0
        self._requeued_jobs = 0
        if journal_path is not None:
            # opening IS recovering: replay whatever the journal holds
            # (nothing, for a fresh path), then compact it down to the
            # equivalent minimal record set
            self._journal = journal_mod.Journal(journal_path,
                                                fault_plan=fault_plan)
            self._replay_journal()
            self._journal.compact(self._compaction_records())
        if autostart:
            self.start()

    @classmethod
    def recover(cls, journal_path: str,
                config: Optional[ForgeConfig] = None,
                **kwargs) -> "ForgeService":
        """Rebuild a service from *journal_path*: every journaled job is
        restored — terminal jobs with their reports, queued and mid-wave
        jobs re-enqueued in original (priority, arrival) order. A thin
        alias for constructing with ``journal_path`` (opening a journal
        always replays it); exists so the restart-after-crash call site
        reads as what it is."""
        return cls(config, journal_path=journal_path, **kwargs)

    # -- journal recovery ------------------------------------------------
    def _replay_journal(self) -> None:
        """Rebuild job records, dedup attachments, and the queue from the
        journal. Runs from ``__init__`` only — no locking needed."""
        terminals: Dict[str, Dict[str, Any]] = {}
        submits: List[Dict[str, Any]] = []
        for rec in self._journal.records:
            if not isinstance(rec, dict):
                continue
            if rec.get("kind") == "submit":
                submits.append(rec)
            elif rec.get("kind") == "terminal":
                terminals[rec["job_id"]] = rec
        # pass 1: restore every job record and its terminal state
        for rec in submits:
            jid = rec["job_id"]
            job = job_codec.decode_job(rec["job"])
            # recompute the exact key instead of persisting it: key
            # derivation is deterministic, and recomputing keeps a journal
            # written under one build honest under the next
            exact_key = compute_job_keys(self.forge.pipeline, job)[0]
            sj = ServiceJob(jid, job, rec.get("client") or DEFAULT_CLIENT,
                            int(rec.get("priority") or 0), exact_key,
                            attached_to=rec.get("attached_to"),
                            seq=int(rec.get("seq") or 0))
            sj.created_s = float(rec.get("created_s") or sj.created_s)
            term = terminals.get(jid)
            if term is not None:
                sj.state = term["state"]
                sj.report = term.get("report")
                sj.error = term.get("error")
                sj.finished_s = term.get("finished_s")
                if sj.report:
                    jobs = sj.report.get("jobs") or []
                    if jobs:    # replay the stage-event buffer: report
                        # stages are the same StageRecord dicts SSE serves
                        sj.events = [dict(s)
                                     for s in jobs[0].get("stages", [])]
            self._jobs[jid] = sj
            self._order.append(jid)
            self._seq = max(self._seq, sj.seq)
        # pass 2: client counters, dedup attachments, re-enqueue
        for rec in submits:
            sj = self._jobs[rec["job_id"]]
            self._count(sj.client, "submitted")
            if sj.attached_to is not None:
                self._count(sj.client, "deduped")
                primary = self._jobs.get(sj.attached_to)
                if sj.state not in _TERMINAL and primary is not None \
                        and primary.state in _TERMINAL:
                    # crash landed between the primary's terminal record
                    # and this attachment's: mirror the primary
                    sj.state = primary.state
                    sj.report = copy.deepcopy(primary.report)
                    sj.error = primary.error
                    sj.finished_s = primary.finished_s
                    sj.events = [dict(e) for e in primary.events]
                if sj.state in _TERMINAL:
                    if sj.state != "cancelled":
                        self._count(sj.client, "completed"
                                    if sj.state == "done" else "failed")
                    continue
                self._attached.setdefault(sj.attached_to, []).append(sj.id)
                continue
            if sj.state in _TERMINAL:
                if sj.state != "cancelled":
                    self._count(sj.client, "completed"
                                if sj.state == "done" else "failed")
                continue
            # queued or mid-wave at crash time: both re-enqueue — a wave
            # with no terminal record never committed, and re-running it
            # is safe (deterministic engine, warm store makes it cheap)
            sj.state = "queued"
            self._inflight_keys[sj.exact_key] = sj.id
            heapq.heappush(self._heap, (-sj.priority, sj.seq, sj.id))
            self._requeued_jobs += 1
        self._recovered_jobs = len(submits)

    def _compaction_records(self) -> List[Dict[str, Any]]:
        """The minimal record set whose replay reproduces current job
        state: one submit per job (original order) plus one terminal per
        finished job."""
        recs: List[Dict[str, Any]] = []
        for jid in self._order:
            sj = self._jobs[jid]
            recs.append(journal_mod.submit_record(
                jid, job_codec.encode_job(sj.job), sj.client, sj.priority,
                sj.seq, sj.created_s, attached_to=sj.attached_to))
            if sj.state in _TERMINAL:
                recs.append(journal_mod.terminal_record(
                    jid, sj.state, sj.report, sj.error,
                    sj.finished_s or 0.0))
        return recs

    def journal_stats(self) -> Optional[Dict[str, Any]]:
        """Journal health for ``/v1/healthz`` and the chaos gate; None
        when the service runs without a journal."""
        if self._journal is None:
            return None
        s = self._journal.stats()
        s["jobs_recovered"] = self._recovered_jobs
        s["jobs_requeued"] = self._requeued_jobs
        return s

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "ForgeService":
        """Launch the dispatcher thread (idempotent)."""
        with self._cv:
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._drain_loop, daemon=True,
                    name="forge-service-dispatcher")
                self._dispatcher.start()
        return self

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Graceful stop: refuse new submissions, then either finish every
        queued job (``drain=True``) or cancel the queue and only finish the
        wave already running. Joins the dispatcher and closes the Forge.
        Idempotent."""
        with self._cv:
            self._accepting = False
            self._stopping = True
            # a crashed dispatcher is a simulated dead process: shutdown
            # is then pure resource teardown — cancelling queued jobs here
            # would journal state transitions the "dead" process never made
            if not drain and not self.dispatcher_crashed:
                while self._heap:
                    _, _, jid = heapq.heappop(self._heap)
                    sj = self._jobs[jid]
                    if sj.state == "queued":
                        self._finish_locked(sj, "cancelled",
                                            error="cancelled at shutdown")
            self._cv.notify_all()
        if self._dispatcher is not None:
            self._dispatcher.join(timeout)
        self.forge.close()
        if self._journal is not None:
            if not self.dispatcher_crashed:
                # a crashed dispatcher means the journal — not memory —
                # is the authoritative state; never compact over it
                with self._cv:
                    self._journal.compact(self._compaction_records())
            self._journal.close()

    def shutdown_intake(self) -> None:
        """Stop accepting submissions but keep draining what's queued (the
        ``POST /v1/admin/drain`` semantics — the dispatcher stays alive so
        SSE streams and ``wait()`` calls still complete)."""
        with self._cv:
            self._accepting = False
            self._cv.notify_all()

    @property
    def draining(self) -> bool:
        return not self._accepting

    def __enter__(self) -> "ForgeService":
        return self.start()

    def __exit__(self, *exc):
        self.shutdown(drain=True)

    # -- submission ------------------------------------------------------
    def submit_wire(self, wire: Dict[str, Any],
                    client: str = DEFAULT_CLIENT,
                    priority: Optional[int] = None) -> Dict[str, Any]:
        """Submit a wire-form kernel job (the HTTP entry point). Raises
        :class:`~repro.core.job_codec.WireDecodeError` on a malformed
        payload — the caller maps it to a 400."""
        job = job_codec.decode_job(wire)
        return self.submit_job(job, client=client, priority=priority)

    def submit_job(self, job: KernelJob, client: str = DEFAULT_CLIENT,
                   priority: Optional[int] = None) -> Dict[str, Any]:
        """Queue one :class:`KernelJob`; returns the submission receipt
        (job id, state, queue position, dedup info). Raises
        :class:`RateLimited` / :class:`ServiceClosed` / :class:`QueueFull`.
        """
        client = client or DEFAULT_CLIENT
        if priority is None:
            priority = self.service_config.default_priority
        self._check_rate_limit(client)
        # exact key outside the lock: fingerprinting walks the graphs
        keys = compute_job_keys(self.forge.pipeline, job)
        exact_key = keys[0]
        with self._cv:
            if not self._accepting:
                self._count(client, "rejected")
                raise ServiceClosed("service is draining; not accepting jobs")
            self._count(client, "submitted")
            jid = f"job-{len(self._jobs):06d}"
            primary_id = self._inflight_keys.get(exact_key)
            if primary_id is not None:
                # cross-request dedup: attach to the in-flight primary.
                # The journal append comes before any acknowledgement
                # (the receipt below IS the 202), so an accepted submit
                # can never be forgotten by a crash.
                sj = ServiceJob(jid, job, client, priority, exact_key,
                                attached_to=primary_id, seq=self._seq)
                if self._journal is not None:
                    self._journal.append(journal_mod.submit_record(
                        jid, job_codec.encode_job(job), client, priority,
                        sj.seq, sj.created_s, attached_to=primary_id))
                primary = self._jobs[primary_id]
                sj.state = primary.state
                sj.started_s = primary.started_s
                sj._started_m = primary._started_m
                sj.events = [dict(e) for e in primary.events]
                self._jobs[jid] = sj
                self._order.append(jid)
                self._attached.setdefault(primary_id, []).append(jid)
                self._count(client, "deduped")
                self._cv.notify_all()
                return {"job_id": jid, "state": sj.state, "deduped": True,
                        "attached_to": primary_id, "queue_position": None}
            depth = self.service_config.max_queue_depth
            if depth and len(self._heap) >= depth:
                self._count(client, "rejected")
                raise QueueFull(f"queue depth limit {depth} reached")
            self._seq += 1
            sj = ServiceJob(jid, job, client, priority, exact_key,
                            seq=self._seq)
            if self._journal is not None:
                # commit to disk BEFORE the receipt: an InjectedCrash /
                # real crash here loses a job the client was never told
                # was accepted — the safe side of the ack boundary
                self._journal.append(journal_mod.submit_record(
                    jid, job_codec.encode_job(job), client, priority,
                    sj.seq, sj.created_s))
            self._jobs[jid] = sj
            self._order.append(jid)
            self._inflight_keys[exact_key] = jid
            heapq.heappush(self._heap, (-priority, self._seq, jid))
            pos = self._queue_position_locked(jid)
            self._cv.notify_all()
            return {"job_id": jid, "state": "queued", "deduped": False,
                    "attached_to": None, "queue_position": pos}

    def _check_rate_limit(self, client: str):
        cfg = self.service_config
        if cfg.rate_per_sec <= 0:
            return
        with self._cv:
            bucket = self._buckets.get(client)
            if bucket is None:
                bucket = self._buckets[client] = _TokenBucket(
                    cfg.rate_per_sec, cfg.burst)
        ok, retry_after = bucket.try_acquire()
        if not ok:
            with self._cv:
                self._count(client, "rate_limited")
            raise RateLimited(client, retry_after)

    def _count(self, client: str, key: str, n: int = 1):
        c = self._clients.setdefault(
            client, {"submitted": 0, "deduped": 0, "rate_limited": 0,
                     "rejected": 0, "completed": 0, "failed": 0})
        c[key] += n

    def _queue_position_locked(self, job_id: str) -> Optional[int]:
        """1-based drain position among queued jobs (heap order)."""
        entries = [e for e in self._heap
                   if self._jobs[e[2]].state == "queued"]
        for pos, (_, _, jid) in enumerate(sorted(entries), start=1):
            if jid == job_id:
                return pos
        return None

    # -- inspection ------------------------------------------------------
    def status(self, job_id: str) -> Dict[str, Any]:
        with self._cv:
            sj = self._jobs.get(job_id)
            if sj is None:
                raise UnknownJob(job_id)
            pos = (self._queue_position_locked(job_id)
                   if sj.state == "queued" and sj.attached_to is None
                   else None)
            return sj.status_dict(queue_position=pos)

    def wait(self, job_id: str,
             timeout: Optional[float] = None) -> Dict[str, Any]:
        """Block until the job reaches a terminal state; returns its final
        status dict. Raises :class:`TimeoutError` on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if job_id not in self._jobs:
                raise UnknownJob(job_id)
            while self._jobs[job_id].state not in _TERMINAL:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"job {job_id} still {self._jobs[job_id].state!r} "
                        f"after {timeout}s")
                self._cv.wait(remaining if remaining is not None else 1.0)
        return self.status(job_id)

    def events(self, job_id: str,
               poll_s: float = 0.25) -> Iterator[Tuple[str, Dict[str, Any]]]:
        """Yield ``(event, data)`` pairs for one job: every buffered stage
        record (so late subscribers replay from the start), then live ones
        as they land, then exactly one terminal ``("done", status)``.

        :class:`UnknownJob` raises *eagerly* (not on first ``next()``) so
        the HTTP layer can answer 404 before committing to SSE headers."""
        with self._cv:
            if job_id not in self._jobs:
                raise UnknownJob(job_id)
        return self._event_stream(job_id, poll_s)

    def _event_stream(self, job_id: str,
                      poll_s: float) -> Iterator[Tuple[str, Dict[str, Any]]]:
        cursor = 0
        while True:
            with self._cv:
                sj = self._jobs[job_id]
                while (cursor >= len(sj.events)
                       and sj.state not in _TERMINAL):
                    self._cv.wait(poll_s)
                pending = [dict(e) for e in sj.events[cursor:]]
                cursor += len(pending)
                terminal = (sj.state in _TERMINAL
                            and cursor >= len(sj.events))
                final = sj.status_dict() if terminal else None
            for rec in pending:         # yield outside the lock
                yield "stage", rec
            if terminal:
                yield "done", final
                return

    def stats(self) -> Dict[str, Any]:
        """Service + engine + verify + store counters in one JSON-safe view
        (the ``GET /v1/stats`` payload)."""
        with self._cv:
            by_state = {s: 0 for s in JOB_STATES}
            for sj in self._jobs.values():
                by_state[sj.state] += 1
            clients = {c: dict(v) for c, v in self._clients.items()}
            queue_depth = sum(1 for e in self._heap
                              if self._jobs[e[2]].state == "queued")
        engine = self.forge.stats.as_dict()
        store_entries = len(self.forge.cache)
        out = {
            "uptime_s": time.monotonic() - self._started_m,
            "accepting": self._accepting,
            "queue_depth": queue_depth,
            "jobs_total": len(self._jobs),
            "jobs_by_state": by_state,
            "engine": engine,
            "verify": self.forge.verify_stats.as_dict(),
            "store": {
                "entries": store_entries,
                "families": len(self.forge.cache.family_sizes()),
                # replay/transfer hits = requests served warm by earlier
                # (possibly other-client) submissions — the multi-tenant
                # warming story in one number
                "warm_serves": engine["cache_hits"]
                + engine["family_transfers"],
            },
            "clients": clients,
        }
        if self._journal is not None:
            out["journal"] = self.journal_stats()
        return out

    # -- dispatcher ------------------------------------------------------
    def _drain_loop(self):
        while True:
            wave = self._next_wave()
            if wave is None:
                return
            if wave:
                try:
                    self._run_wave(wave)
                except InjectedCrash:
                    # simulated process death: halt exactly as a killed
                    # process would — no cleanup, no state repair. The
                    # journal is now the only authoritative state;
                    # recovery is ForgeService.recover(journal_path).
                    with self._cv:
                        self.dispatcher_crashed = True
                        self._cv.notify_all()
                    return

    def _next_wave(self) -> Optional[List[ServiceJob]]:
        """Block for queued jobs; pop up to ``wave_size`` in priority order.
        Returns None when stopping and nothing is left to drain."""
        with self._cv:
            while not self._heap and not self._stopping:
                self._cv.wait(0.5)
            if not self._heap:
                return None          # stopping and drained
            wave: List[ServiceJob] = []
            now = time.time()
            now_m = time.monotonic()
            while self._heap and len(wave) < self.service_config.wave_size:
                _, _, jid = heapq.heappop(self._heap)
                sj = self._jobs[jid]
                if sj.state != "queued":
                    continue
                sj.state = "running"
                sj.started_s = now
                sj._started_m = now_m
                for aid in self._attached.get(jid, ()):
                    self._jobs[aid].state = "running"
                    self._jobs[aid].started_s = now
                    self._jobs[aid]._started_m = now_m
                wave.append(sj)
            self._cv.notify_all()
            return wave

    def _run_wave(self, wave: List[ServiceJob]):
        plan = self._fault_plan
        wave_no = plan.next_wave() if plan is not None else 0
        jobs = [sj.job for sj in wave]
        try:
            report = self.forge.optimize_batch(
                jobs, observer=_WaveObserver(self, wave))
        except InjectedCrash:
            raise   # simulated process death, not a job failure
        except Exception:   # noqa: BLE001 — a wave failure must not kill
            tb = traceback.format_exc()     # the dispatcher
            with self._cv:
                for sj in wave:
                    self._finish_locked(sj, "failed", error=tb)
                self._cv.notify_all()
            return
        # _finish_locked commits the terminal journal records, so the two
        # crash points bracket that commit: "before" leaves the wave's
        # jobs journal-queued (recovery re-runs them), "after" leaves
        # them journal-done (recovery restores the reports)
        if plan is not None and plan.should_crash_dispatcher(
                wave_no, "before-journal"):
            raise InjectedCrash(
                f"dispatcher crash before journal commit (wave {wave_no})")
        with self._cv:
            for sj, eres in zip(wave, report.results):
                per_job = OptimizationReport.from_result(
                    eres, self.forge.config).as_dict()
                self._finish_locked(sj, "done", report=per_job)
            self._cv.notify_all()
        if plan is not None and plan.should_crash_dispatcher(
                wave_no, "after-journal"):
            raise InjectedCrash(
                f"dispatcher crash after journal commit (wave {wave_no})")

    def _finish_locked(self, sj: ServiceJob, state: str,
                       report: Optional[Dict[str, Any]] = None,
                       error: Optional[str] = None):
        """Move a primary job (and everything attached to it) to a terminal
        state. Attached jobs get a deep copy of the report — identical
        content, no shared mutable aliasing between tenants."""
        now = time.time()
        now_m = time.monotonic()
        stat = "completed" if state == "done" else "failed"
        for target in [sj] + [self._jobs[a]
                              for a in self._attached.pop(sj.id, ())]:
            target.state = state
            target.finished_s = now
            target._finished_m = now_m
            target.error = error
            target.report = (None if report is None
                             else copy.deepcopy(report))
            if state != "cancelled":
                self._count(target.client, stat)
            if self._journal is not None:
                # one terminal record per target (attached included), so
                # recovery restores each job's outcome independently
                self._journal.append(journal_mod.terminal_record(
                    target.id, state, target.report, error, now))
        self._inflight_keys.pop(sj.exact_key, None)
