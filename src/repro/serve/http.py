"""HTTP front-end for :class:`~repro.serve.service.ForgeService`.

Stdlib-only (``http.server.ThreadingHTTPServer``): one handler thread per
connection, which is exactly right for this service's scale — the heavy
work happens on the dispatcher thread, handlers just move JSON and block on
condition variables. Endpoints:

* ``POST /v1/jobs`` — submit a wire-form kernel job
  (``{"job": <encode_job(...)>, "priority": int?}``); returns 202 with the
  job id and queue position. Malformed payloads are 400 with the
  :class:`WireDecodeError` message — including
  :class:`~repro.core.job_codec.WireVersionError` (a payload declaring a
  ``wire_version`` this build does not speak), whose message names the
  supported versions; over-budget clients get 429 with ``Retry-After``;
  a draining service answers 503.
* ``GET /v1/jobs/{id}`` — status, including the full
  ``OptimizationReport.as_dict()`` once the job is done.
* ``GET /v1/jobs/{id}/events`` — Server-Sent-Events stream of the job's
  stage records (buffered ones replay first), terminated by one ``done``
  event carrying the final status.
* ``GET /v1/stats`` — service + engine + verify + store counters.
* ``GET /v1/healthz`` — liveness (``{"ok": true, ...}``).
* ``POST /v1/admin/drain`` — stop intake; in-queue jobs still finish.

Clients identify themselves with ``X-API-Key: <token>`` (or
``Authorization: Bearer <token>``); without one they share the
``anonymous`` rate bucket.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

# WireVersionError subclasses WireDecodeError, so a version-mismatched
# payload takes the same 400 path as any other malformed wire form —
# imported explicitly to pin that contract (tests import it from here)
from repro.core.job_codec import WireDecodeError, WireVersionError  # noqa: F401
from repro.serve.service import (DEFAULT_CLIENT, ForgeService, QueueFull,
                                 RateLimited, ServiceClosed, UnknownJob)

__all__ = ["ForgeServiceServer", "ForgeRequestHandler", "serve_forever"]

_MAX_BODY = 32 * 1024 * 1024    # 32 MiB — wire jobs embed base64 arrays


class ForgeRequestHandler(BaseHTTPRequestHandler):
    """Routes /v1/* onto the owning server's ForgeService."""

    server_version = "forge-serve/1.0"
    protocol_version = "HTTP/1.1"

    # quiet by default; the server can install a logger
    def log_message(self, fmt, *args):  # noqa: A003
        log = getattr(self.server, "request_log", None)
        if log is not None:
            log(fmt % args)

    @property
    def service(self) -> ForgeService:
        return self.server.service  # type: ignore[attr-defined]

    # -- plumbing --------------------------------------------------------
    def _client_token(self) -> str:
        tok = self.headers.get("X-API-Key")
        if not tok:
            auth = self.headers.get("Authorization", "")
            if auth.startswith("Bearer "):
                tok = auth[len("Bearer "):].strip()
        return tok or DEFAULT_CLIENT

    def _send_json(self, code: int, payload: Dict[str, Any],
                   headers: Optional[Dict[str, str]] = None):
        body = json.dumps(payload).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str,
               headers: Optional[Dict[str, str]] = None):
        self._send_json(code, {"error": message}, headers=headers)

    def _read_json(self) -> Dict[str, Any]:
        length = int(self.headers.get("Content-Length") or 0)
        if length <= 0:
            raise WireDecodeError("empty request body")
        if length > _MAX_BODY:
            raise WireDecodeError(f"request body exceeds {_MAX_BODY} bytes")
        raw = self.rfile.read(length)
        try:
            payload = json.loads(raw)
        except json.JSONDecodeError as exc:
            raise WireDecodeError(f"request body is not JSON: {exc}")
        if not isinstance(payload, dict):
            raise WireDecodeError("request body must be a JSON object")
        return payload

    def _job_route(self) -> Optional[Tuple[str, Optional[str]]]:
        """Parse ``/v1/jobs/{id}[/events]`` -> (job_id, sub) or None."""
        parts = [p for p in self.path.split("?", 1)[0].split("/") if p]
        if len(parts) < 3 or parts[0] != "v1" or parts[1] != "jobs":
            return None
        if len(parts) == 3:
            return parts[2], None
        if len(parts) == 4:
            return parts[2], parts[3]
        return None

    # -- verbs -----------------------------------------------------------
    def do_GET(self):  # noqa: N802
        path = self.path.split("?", 1)[0]
        if path == "/v1/healthz":
            svc = self.service
            payload: Dict[str, Any] = {
                "ok": True, "accepting": not svc.draining}
            # journal key only when a journal is configured — a plain
            # in-memory service answers exactly as before
            js = svc.journal_stats()
            if js is not None:
                payload["journal"] = js
                if getattr(svc, "dispatcher_crashed", False):
                    payload["ok"] = False
            return self._send_json(200, payload)
        if path == "/v1/stats":
            return self._send_json(200, self.service.stats())
        route = self._job_route()
        if route is not None:
            job_id, sub = route
            if sub is None:
                return self._get_job(job_id)
            if sub == "events":
                return self._stream_events(job_id)
        self._error(404, f"no such resource: {path}")

    def do_POST(self):  # noqa: N802
        path = self.path.split("?", 1)[0]
        if path == "/v1/jobs":
            return self._post_job()
        if path == "/v1/admin/drain":
            self.service.shutdown_intake()
            return self._send_json(200, {"accepting": False})
        self._error(404, f"no such resource: {path}")

    # -- handlers --------------------------------------------------------
    def _post_job(self):
        client = self._client_token()
        try:
            payload = self._read_json()
            wire = payload.get("job")
            if not isinstance(wire, dict):
                raise WireDecodeError('payload must carry a "job" object '
                                      "(the encode_job wire form)")
            priority = payload.get("priority")
            if priority is not None and not isinstance(priority, int):
                raise WireDecodeError('"priority" must be an integer')
            receipt = self.service.submit_wire(wire, client=client,
                                               priority=priority)
        except WireDecodeError as exc:
            return self._error(400, str(exc))
        except RateLimited as exc:
            return self._error(
                429, str(exc),
                headers={"Retry-After": f"{exc.retry_after_s:.2f}"})
        except (ServiceClosed, QueueFull) as exc:
            return self._error(503, str(exc))
        self._send_json(202, receipt)

    def _get_job(self, job_id: str):
        try:
            status = self.service.status(job_id)
        except UnknownJob:
            return self._error(404, f"unknown job: {job_id}")
        self._send_json(200, status)

    def _stream_events(self, job_id: str):
        try:
            stream = self.service.events(job_id)
        except UnknownJob:
            return self._error(404, f"unknown job: {job_id}")
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        # SSE is open-ended: no Content-Length, so close delimits the body
        self.send_header("Connection", "close")
        self.end_headers()
        try:
            for event, data in stream:
                chunk = (f"event: {event}\n"
                         f"data: {json.dumps(data)}\n\n")
                self.wfile.write(chunk.encode("utf-8"))
                self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            pass    # client hung up mid-stream; nothing to clean up
        self.close_connection = True


class ForgeServiceServer(ThreadingHTTPServer):
    """ThreadingHTTPServer bound to one ForgeService.

    ``daemon_threads`` so a lingering SSE reader can't block process exit;
    ``serve/close`` are explicit so callers (CLI, CI gate, tests) control
    the lifecycle. Known limitation, by design: this is the stdlib server —
    no TLS, HTTP/1.1 only, thread-per-connection. See ROADMAP ("hosted
    service" item) for the production-transport follow-ups.
    """

    daemon_threads = True
    allow_reuse_address = True
    request_log = None          # callable(str) or None

    def __init__(self, address: Tuple[str, int], service: ForgeService):
        super().__init__(address, ForgeRequestHandler)
        self.service = service

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def serve_background(self) -> threading.Thread:
        """Run ``serve_forever`` on a daemon thread; returns the thread."""
        t = threading.Thread(target=self.serve_forever, daemon=True,
                             name="forge-serve-http")
        t.start()
        return t

    def shutdown_all(self, drain: bool = True):
        """Stop the HTTP loop, then drain-and-stop the service."""
        self.shutdown()
        self.server_close()
        self.service.shutdown(drain=drain)


def serve_forever(service: ForgeService, host: str = "127.0.0.1",
                  port: int = 8787) -> None:
    """Blocking convenience runner (the ``__main__`` entry uses it)."""
    server = ForgeServiceServer((host, port), service)
    try:
        server.serve_forever()
    finally:
        server.shutdown_all(drain=True)
