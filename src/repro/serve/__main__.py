"""``python -m repro.serve`` / ``forge-serve``: run the Forge service.

Binds the stdlib HTTP front-end to a fresh :class:`ForgeService` and blocks
until SIGINT/SIGTERM, then drains (in-queue jobs finish; intake stops)::

    forge-serve --port 8787 --workers 4 --cache-path results/store.json \\
                --rate-limit 2.0 --burst 8
"""

from __future__ import annotations

import argparse
import signal
import sys
import threading

from repro.core.config import EXECUTION_BACKENDS, ForgeConfig
from repro.serve.http import ForgeServiceServer
from repro.serve.service import ForgeService, ServiceConfig


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="forge-serve",
        description="Hosted Forge kernel-optimization service "
                    "(stdlib HTTP; see README 'Forge service').")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8787)
    # optimization policy (forwarded to ForgeConfig)
    p.add_argument("--spec", default="tpu_v5e", dest="spec_name",
                   help="hardware spec name (ForgeConfig.spec_name)")
    p.add_argument("--max-iterations", type=int, default=5)
    p.add_argument("--workers", type=int, default=1,
                   help="engine workers per wave")
    p.add_argument("--backend", default="thread",
                   choices=sorted(EXECUTION_BACKENDS),
                   help="engine execution backend")
    p.add_argument("--cache-path", default=None,
                   help="persist the shared result store here")
    p.add_argument("--journal", default=None, metavar="PATH",
                   help="crash-safe job journal: submits are committed "
                        "here before they are acknowledged, and starting "
                        "against an existing journal recovers every "
                        "queued/mid-wave job (see README 'Durability & "
                        "fault injection')")
    # distributed fleet (--backend remote; see README 'Distributed fleet')
    p.add_argument("--fleet-address", default=None, metavar="HOST:PORT",
                   help="bind the fleet coordinator here so forge-worker "
                        "processes on other hosts can join (default: "
                        "loopback, ephemeral port)")
    p.add_argument("--fleet-workers", type=int, default=None, metavar="N",
                   help="local forge-worker processes to spawn (default: "
                        "--workers; 0 = external workers only)")
    # service shape
    p.add_argument("--wave-size", type=int, default=4,
                   help="max jobs batched into one engine wave")
    p.add_argument("--max-queue-depth", type=int, default=1024,
                   help="queued-job limit (0 = unbounded)")
    p.add_argument("--rate-limit", type=float, default=0.0,
                   help="per-client tokens/sec (0 disables rate limiting)")
    p.add_argument("--burst", type=int, default=8,
                   help="per-client token-bucket capacity")
    p.add_argument("--quiet", action="store_true",
                   help="suppress request logging")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    config = ForgeConfig(spec_name=args.spec_name,
                         max_iterations=args.max_iterations,
                         workers=args.workers,
                         execution_backend=args.backend,
                         cache_path=args.cache_path,
                         fleet_address=args.fleet_address,
                         fleet_spawn_workers=args.fleet_workers)
    service = ForgeService(
        config,
        service_config=ServiceConfig(wave_size=args.wave_size,
                                     max_queue_depth=args.max_queue_depth,
                                     rate_per_sec=args.rate_limit,
                                     burst=args.burst),
        journal_path=args.journal)
    if args.journal and not args.quiet:
        js = service.journal_stats()
        print(f"[forge-serve] journal {args.journal}: "
              f"{js['jobs_recovered']} jobs recovered, "
              f"{js['jobs_requeued']} requeued", file=sys.stderr)
    server = ForgeServiceServer((args.host, args.port), service)
    if not args.quiet:
        server.request_log = lambda line: print(f"[forge-serve] {line}",
                                                file=sys.stderr)
    stop = threading.Event()

    def _on_signal(signum, frame):
        print(f"[forge-serve] signal {signum}: draining...",
              file=sys.stderr)
        stop.set()

    signal.signal(signal.SIGINT, _on_signal)
    signal.signal(signal.SIGTERM, _on_signal)

    thread = server.serve_background()
    print(f"[forge-serve] listening on {server.url} "
          f"(wave_size={args.wave_size}, workers={args.workers}, "
          f"backend={args.backend})", file=sys.stderr)
    try:
        while not stop.is_set():
            stop.wait(0.5)
    finally:
        server.shutdown_all(drain=True)
        thread.join(timeout=5)
        print("[forge-serve] drained and stopped", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
