"""Serving layer: the model serve engine and the Forge optimization
service.

Two related-but-separate subsystems live here:

* :mod:`repro.serve.engine` — the slot-batched model *inference* engine
  (prompt prefill + greedy decode) used by ``repro.launch.serve``.
* :mod:`repro.serve.service` / :mod:`repro.serve.http` /
  :mod:`repro.serve.client` — the hosted *kernel optimization* service:
  multi-tenant job queue over one :class:`~repro.core.forge.Forge`, an
  stdlib HTTP front-end with SSE stage streaming, and the Python client.
  ``python -m repro.serve`` (or the ``forge-serve`` console script) runs
  the server.

Re-exports resolve lazily so importing the lightweight client never drags
in the jax-backed inference engine (and vice versa).
"""

from __future__ import annotations

__all__ = [
    # model inference engine
    "Request", "ServeEngine",
    # optimization service
    "ForgeService", "ServiceConfig", "ServiceJob",
    "RateLimited", "ServiceClosed", "QueueFull", "UnknownJob",
    # HTTP layer
    "ForgeServiceServer", "ForgeRequestHandler", "serve_forever",
    # client
    "ForgeClient", "ServiceError", "StreamInterrupted",
]

_EXPORTS = {
    "Request": "repro.serve.engine",
    "ServeEngine": "repro.serve.engine",
    "ForgeService": "repro.serve.service",
    "ServiceConfig": "repro.serve.service",
    "ServiceJob": "repro.serve.service",
    "RateLimited": "repro.serve.service",
    "ServiceClosed": "repro.serve.service",
    "QueueFull": "repro.serve.service",
    "UnknownJob": "repro.serve.service",
    "ForgeServiceServer": "repro.serve.http",
    "ForgeRequestHandler": "repro.serve.http",
    "serve_forever": "repro.serve.http",
    "ForgeClient": "repro.serve.client",
    "ServiceError": "repro.serve.client",
    "StreamInterrupted": "repro.serve.client",
}


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(module), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))
