"""ForgeClient: the Python client for a running Forge service.

Stdlib ``http.client`` — the client must import cleanly in environments
that have nothing but Python (the CI gate runs it in a subprocess). The
high-level call mirrors the local facade::

    client = ForgeClient("http://127.0.0.1:8787", api_key="team-a")
    report = client.optimize(job)          # submit -> wait -> report dict

and the lower-level pieces (``submit`` / ``status`` / ``wait`` /
``events``) expose the queue mechanics for tests and dashboards.
"""

from __future__ import annotations

import http.client
import json
import time
import urllib.parse
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core import job_codec
from repro.core.engine import KernelJob
from repro.core.faults import deterministic_backoff

__all__ = ["ForgeClient", "ServiceError", "StreamInterrupted"]


class ServiceError(Exception):
    """Non-2xx response from the service."""

    def __init__(self, status: int, message: str,
                 retry_after_s: Optional[float] = None):
        self.status = status
        self.retry_after_s = retry_after_s
        super().__init__(f"HTTP {status}: {message}")


class StreamInterrupted(ServiceError):
    """An SSE stream ended before its terminal ``done`` event — the
    connection dropped (server restart, network) rather than the job
    finishing. Status 0: there was no HTTP error, the transport died."""

    def __init__(self, job_id: str, events_seen: int):
        super().__init__(0, f"event stream for job {job_id} dropped after "
                            f"{events_seen} events without a 'done' event")
        self.job_id = job_id
        self.events_seen = events_seen


def _poll_backoff(job_id: str, attempt: int, base_s: float = 0.05,
                  cap_s: float = 2.0) -> float:
    """Capped exponential backoff with deterministic sha256 jitter —
    now the shared :func:`repro.core.faults.deterministic_backoff`
    schedule (this alias keeps the historical name and its byte-exact
    sleep sequence: same formula, same digest keying)."""
    return deterministic_backoff(job_id, attempt, base_s=base_s,
                                 cap_s=cap_s)


class ForgeClient:
    """Thin HTTP client for the Forge service. One connection per request
    (the service's SSE responses are close-delimited, so pooling buys
    nothing at this scale and keeps the client trivially thread-safe)."""

    def __init__(self, base_url: str, api_key: Optional[str] = None,
                 timeout: float = 60.0, retry_on_rate_limit: bool = False,
                 rate_limit_retries: int = 5):
        parsed = urllib.parse.urlsplit(base_url)
        if parsed.scheme != "http":
            raise ValueError(f"only http:// is supported, got {base_url!r}")
        self.host = parsed.hostname or "127.0.0.1"
        self.port = parsed.port or 80
        self.api_key = api_key
        self.timeout = timeout
        # opt-in: honor the server's Retry-After on a 429 instead of
        # raising immediately, bounded to rate_limit_retries attempts —
        # a client in a submit loop rides out its token bucket without
        # hand-rolled sleep logic, but can never spin forever
        self.retry_on_rate_limit = retry_on_rate_limit
        self.rate_limit_retries = max(0, int(rate_limit_retries))

    # -- transport -------------------------------------------------------
    def _headers(self) -> Dict[str, str]:
        h = {"Accept": "application/json"}
        if self.api_key:
            h["X-API-Key"] = self.api_key
        return h

    def _request(self, method: str, path: str,
                 body: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        attempt = 0
        while True:
            try:
                return self._request_once(method, path, body)
            except ServiceError as exc:
                if (not self.retry_on_rate_limit or exc.status != 429
                        or exc.retry_after_s is None
                        or attempt >= self.rate_limit_retries):
                    raise
                # the server's hint, capped so a pathological Retry-After
                # can't park the client; no extra jitter needed — the
                # hint already reflects this client's private bucket
                time.sleep(min(max(0.0, exc.retry_after_s), 30.0))
                attempt += 1

    def _request_once(self, method: str, path: str,
                      body: Optional[Dict[str, Any]] = None
                      ) -> Dict[str, Any]:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            headers = self._headers()
            payload = None
            if body is not None:
                payload = json.dumps(body).encode("utf-8")
                headers["Content-Type"] = "application/json"
            conn.request(method, path, body=payload, headers=headers)
            resp = conn.getresponse()
            raw = resp.read()
            try:
                data = json.loads(raw) if raw else {}
            except json.JSONDecodeError:
                data = {"error": raw.decode("utf-8", "replace")}
            if resp.status >= 400:
                retry = resp.headers.get("Retry-After")
                raise ServiceError(
                    resp.status, data.get("error", "request failed"),
                    retry_after_s=float(retry) if retry else None)
            return data
        finally:
            conn.close()

    # -- endpoints -------------------------------------------------------
    def submit(self, job: KernelJob,
               priority: Optional[int] = None) -> Dict[str, Any]:
        """POST the job in wire form; returns the submission receipt
        (``job_id``, ``state``, ``queue_position``, ``deduped``)."""
        body: Dict[str, Any] = {"job": job_codec.encode_job(job)}
        if priority is not None:
            body["priority"] = priority
        return self._request("POST", "/v1/jobs", body=body)

    def submit_wire(self, wire: Dict[str, Any],
                    priority: Optional[int] = None) -> Dict[str, Any]:
        """POST an already-encoded job payload (malformed-input tests)."""
        body: Dict[str, Any] = {"job": wire}
        if priority is not None:
            body["priority"] = priority
        return self._request("POST", "/v1/jobs", body=body)

    def status(self, job_id: str) -> Dict[str, Any]:
        return self._request("GET", f"/v1/jobs/{job_id}")

    def wait(self, job_id: str, timeout: float = 300.0,
             poll_s: Optional[float] = None) -> Dict[str, Any]:
        """Poll until the job is terminal; returns the final status dict
        (``report`` included on success).

        By default polling backs off exponentially (50ms doubling to a
        2s cap) with deterministic per-job jitter — see
        :func:`_poll_backoff` — instead of hammering the service on a
        fixed short interval. Pass an explicit ``poll_s`` to restore a
        fixed cadence (tests that need tight latency bounds)."""
        deadline = time.monotonic() + timeout
        attempt = 0
        while True:
            status = self.status(job_id)
            if status["state"] in ("done", "failed", "cancelled"):
                return status
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"job {job_id} still {status['state']!r} "
                    f"after {timeout}s")
            sleep_s = (poll_s if poll_s is not None
                       else _poll_backoff(job_id, attempt))
            time.sleep(min(sleep_s, max(0.0, deadline - time.monotonic())))
            attempt += 1

    def events(self, job_id: str, timeout: Optional[float] = None
               ) -> Iterator[Tuple[str, Dict[str, Any]]]:
        """Stream the job's SSE feed; yields ``(event, data)`` pairs and
        returns after the terminal ``done`` event. A connection that
        drops before ``done`` raises :class:`StreamInterrupted` instead
        of silently ending the iterator."""
        conn = http.client.HTTPConnection(
            self.host, self.port,
            timeout=timeout if timeout is not None else self.timeout)
        try:
            conn.request("GET", f"/v1/jobs/{job_id}/events",
                         headers=self._headers())
            resp = conn.getresponse()
            if resp.status >= 400:
                raw = resp.read()
                try:
                    msg = json.loads(raw).get("error", "stream failed")
                except json.JSONDecodeError:
                    msg = raw.decode("utf-8", "replace")
                raise ServiceError(resp.status, msg)
            event, data_lines = None, []  # type: ignore[var-annotated]
            seen = 0
            try:
                for raw_line in resp:
                    line = raw_line.decode("utf-8").rstrip("\n").rstrip("\r")
                    if line.startswith("event:"):
                        event = line[len("event:"):].strip()
                    elif line.startswith("data:"):
                        data_lines.append(line[len("data:"):].strip())
                    elif not line and event is not None:
                        yield event, json.loads("\n".join(data_lines) or "{}")
                        seen += 1
                        if event == "done":
                            return
                        event, data_lines = None, []
            except (ConnectionError, http.client.HTTPException,
                    TimeoutError, OSError) as exc:
                raise StreamInterrupted(job_id, seen) from exc
            # orderly EOF without 'done': the server went away mid-stream
            raise StreamInterrupted(job_id, seen)
        finally:
            conn.close()

    def stats(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/stats")

    def healthz(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/healthz")

    def drain(self) -> Dict[str, Any]:
        """Stop the service's intake (queued jobs still finish)."""
        return self._request("POST", "/v1/admin/drain")

    # -- high-level ------------------------------------------------------
    def optimize(self, job: KernelJob, priority: Optional[int] = None,
                 timeout: float = 300.0) -> Dict[str, Any]:
        """Submit and block for the result; returns the service-side
        ``OptimizationReport.as_dict()`` payload. Raises ``RuntimeError``
        if the job failed server-side."""
        receipt = self.submit(job, priority=priority)
        status = self.wait(receipt["job_id"], timeout=timeout)
        if status["state"] != "done":
            raise RuntimeError(
                f"job {receipt['job_id']} ended {status['state']!r}: "
                f"{status.get('error', 'no detail')}")
        return status["report"]

    def optimize_many(self, jobs: List[KernelJob],
                      timeout: float = 600.0) -> List[Dict[str, Any]]:
        """Submit all jobs up front (so the service can batch/dedup), then
        collect every report in submission order."""
        receipts = [self.submit(j) for j in jobs]
        out = []
        for r in receipts:
            status = self.wait(r["job_id"], timeout=timeout)
            if status["state"] != "done":
                raise RuntimeError(
                    f"job {r['job_id']} ended {status['state']!r}: "
                    f"{status.get('error', 'no detail')}")
            out.append(status["report"])
        return out

    def wait_ready(self, timeout: float = 30.0, poll_s: float = 0.2
                   ) -> Dict[str, Any]:
        """Block until /v1/healthz answers (server startup handshake)."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return self.healthz()
            except (ConnectionError, OSError, ServiceError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(poll_s)
