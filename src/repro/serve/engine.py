"""Batched serving engine: prompt prefill + greedy decode over a slot batch.

Admission is batch-synchronous (a wave of equal-length prompts fills the
slots, decodes in lockstep, then the next wave admits) — the slot/cache
plumbing that a continuous-batching scheduler would drive; the multi-pod
serving path (sharded caches, split-KV decode) is exercised by the dry-run
cells rather than this CPU-scale engine.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import RuntimeFlags, decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32 (equal length within a wave)
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 slots: int = 4,
                 flags: RuntimeFlags = RuntimeFlags(remat=False)):
        self.cfg = cfg
        self.params = params
        self.flags = flags
        self.max_len = max_len
        self.slots = slots
        # deque: wave admission pops from the head (popleft is O(1); the
        # old list.pop(0) shifted the whole backlog per admitted request)
        self.queue: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, flags),
            static_argnums=())

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _run_wave(self):
        wave = [self.queue.popleft()
                for _ in range(min(self.slots, len(self.queue)))]
        if not wave:
            return
        plen = len(wave[0].prompt)
        assert all(len(r.prompt) == plen for r in wave), \
            "wave admission requires equal-length prompts"
        cache = init_cache(self.cfg, self.slots, self.max_len, jnp.float32)
        # prefill via lockstep decode steps (slot-batched)
        logits = None
        for t in range(plen):
            toks = np.zeros((self.slots, 1), np.int32)
            for i, r in enumerate(wave):
                toks[i, 0] = int(r.prompt[t])
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks), t)
        # greedy decode
        pos = plen
        alive = list(range(len(wave)))
        nxt = np.argmax(np.asarray(logits), axis=-1)
        while alive and pos < self.max_len:
            toks = np.zeros((self.slots, 1), np.int32)
            for i in alive:
                wave[i].generated.append(int(nxt[i]))
                toks[i, 0] = int(nxt[i])
            alive = [i for i in alive
                     if len(wave[i].generated) < wave[i].max_new_tokens]
            if not alive:
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks), pos)
            nxt = np.argmax(np.asarray(logits), axis=-1)
            pos += 1
        for r in wave:
            r.done = True
            self.finished.append(r)

    # ------------------------------------------------------------------
    def run(self, max_waves: int = 64) -> List[Request]:
        waves = 0
        while self.queue and waves < max_waves:
            self._run_wave()
            waves += 1
        return self.finished
