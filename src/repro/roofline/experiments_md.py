"""Generate the data-driven sections of EXPERIMENTS.md from the dry-run JSON.

    PYTHONPATH=src python -m repro.roofline.experiments_md results/dryrun/all.json
"""

from __future__ import annotations

import json
import pathlib
import sys
from typing import Dict, List

from repro.roofline.analyze import from_record, what_moves_it


def dryrun_section(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skip"]
    bad = [r for r in recs if r.get("status") not in ("ok", "skip")]
    lines = ["## §Dry-run", ""]
    lines.append(f"**{len(ok)} cells compiled** (`.lower().compile()` on the "
                 f"production meshes), **{len(skip)} skipped** per the "
                 f"long-context applicability rule, **{len(bad)} failed**.")
    lines.append("")
    lines.append("Mesh: single pod = `(16,16)` (data, model) = 256 chips; "
                 "multi-pod = `(2,16,16)` (pod, data, model) = 512 chips "
                 "(512 forced host devices; ShapeDtypeStruct inputs — no "
                 "allocation).")
    lines.append("")
    lines.append("| arch | shape | mesh | compile s | HLO flops/chip | "
                 "bytes/chip | coll. bytes/chip | peak mem/chip (proj.) | fits 16 GiB |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        m = r["memory"]
        lines.append(
            "| {arch} | {shape} | {mesh} | {c:.0f} | {f:.2e} | {b:.2e} | "
            "{cb:.2e} | {pk:.1f} GiB | {fits} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                c=r.get("compile_s", 0), f=r["cost"]["flops"],
                b=r["cost"]["bytes"],
                cb=r["collectives"].get("total", 0),
                pk=m["peak_projected_tpu"] / 2**30,
                fits="yes" if r.get("fits_hbm") else "**no**"))
    if skip:
        lines.append("")
        lines.append("Skipped cells (assignment rule — `long_500k` needs "
                     "sub-quadratic attention):")
        for r in sorted(skip, key=lambda r: (r["mesh"], r["arch"])):
            lines.append(f"- {r['arch']} x {r['shape']} ({r['mesh']}): "
                         f"{r.get('reason', '')}")
    return "\n".join(lines)


def roofline_section(recs: List[Dict]) -> str:
    lines = ["## §Roofline", ""]
    lines.append("Terms per the assignment (v5e: 197 TFLOP/s bf16, 819 GB/s "
                 "HBM, 50 GB/s ICI link): `compute = HLO_FLOPs/(chips x peak)`, "
                 "`memory = HLO_bytes/(chips x bw)`, `collective = "
                 "collective_bytes/link_bw` (per-chip payloads parsed from the "
                 "optimized HLO). `useful` = MODEL_FLOPS/HLO_FLOPs (6ND train, "
                 "2ND inference; N_active for MoE). `roofline%` = useful "
                 "FLOPs/chip at the max-term step time vs. chip peak.")
    lines.append("")
    for mesh in ("single", "multipod"):
        rows = [r for r in recs
                if r.get("status") == "ok" and r["mesh"] == mesh]
        if not rows:
            continue
        chips = rows[0]["n_devices"]
        lines.append(f"### {mesh} ({chips} chips)")
        lines.append("")
        lines.append("| arch | shape | compute ms | memory ms | coll. ms | "
                     "dominant | useful | roofline% | what moves it |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            t = from_record(r)
            lines.append(
                "| {a} | {s} | {c:.2f} | {m:.2f} | {co:.2f} | {d} | {u:.2f} | "
                "{rf:.1f}% | {wm} |".format(
                    a=t.arch, s=t.shape, c=t.t_compute * 1e3,
                    m=t.t_memory * 1e3, co=t.t_collective * 1e3,
                    d=t.dominant, u=t.useful_ratio,
                    rf=100 * t.roofline_fraction, wm=what_moves_it(t)))
        lines.append("")
    return "\n".join(lines)


def main():
    path = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                        else "results/dryrun/all.json")
    recs = json.loads(path.read_text())
    print(dryrun_section(recs))
    print()
    print(roofline_section(recs))


if __name__ == "__main__":
    main()
