"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, per the assignment:

    compute    = HLO_FLOPs            / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes            / (chips x 819e9  B/s HBM)
    collective = collective_bytes     / (chips x 50e9   B/s ICI link)

``compiled.cost_analysis()`` on this backend reports *per-device* flops and
bytes, so HLO_FLOPs = cost['flops'] x chips; the formulas then reduce to
per-chip terms. collective_bytes comes from parsing the optimized HLO
(``compiled.as_text()``): the summed output-operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

from repro.hw.specs import TPUSpec, TPU_V5E

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# shapes like  bf16[16,1024,128]{2,1,0}  or tuples ( ..., ... )
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},0-9]+)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[\s.(]", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> List[Tuple[str, int]]:
    """Return [(op_kind, payload_bytes)] for every collective in the HLO."""
    out = []
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if "-start" in hlo_text[m.start():m.end() + 8]:
            pass  # async pairs: count the start, the -done carries no payload
        out.append((kind, _shape_bytes(shape_str)))
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    per_kind: Dict[str, float] = {}
    for kind, nbytes in parse_collectives(hlo_text):
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
    per_kind["total"] = sum(v for k, v in per_kind.items() if k != "total")
    return per_kind


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # total across chips
    hlo_bytes: float              # total across chips
    coll_bytes: float             # per-chip payload total
    model_flops: float            # 6ND / 2ND-style useful flops
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0     # MODEL_FLOPS / HLO_FLOPs
    comment: str = ""

    def finalize(self, spec: TPUSpec = TPU_V5E) -> "RooflineTerms":
        self.t_compute = self.hlo_flops / (self.chips * spec.peak_flops_bf16)
        self.t_memory = self.hlo_bytes / (self.chips * spec.hbm_bw)
        self.t_collective = self.coll_bytes / spec.ici_link_bw
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.dominant = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / self.hlo_flops
                             if self.hlo_flops else 0.0)
        return self

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful compute achieved at the modeled step time vs. chip peak."""
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips / t) / TPU_V5E.peak_flops_bf16


def from_record(rec: Dict) -> RooflineTerms:
    """Build terms from a dry-run JSON record."""
    rt = RooflineTerms(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rec["n_devices"],
        hlo_flops=rec["cost"]["flops"] * rec["n_devices"],
        hlo_bytes=rec["cost"]["bytes"] * rec["n_devices"],
        coll_bytes=rec["collectives"].get("total", 0.0),
        model_flops=rec["model_flops"],
    )
    return rt.finalize()


def what_moves_it(rt: RooflineTerms) -> str:
    if rt.dominant == "compute":
        if rt.useful_ratio < 0.5:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "or fuse the attention score chain")
        return "compute-bound: bf16 everywhere + bigger per-chip batch"
    if rt.dominant == "memory":
        return ("HBM-bound: fuse elementwise chains, keep KV/activations bf16, "
                "raise arithmetic intensity with larger tiles")
    return ("collective-bound: reshard to cut all-gathers (sequence-shard "
            "attention), overlap collectives with compute, or compress "
            "cross-pod payloads (int8 EF)")
