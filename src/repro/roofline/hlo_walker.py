"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` on this backend counts each while-loop body
ONCE, so anything inside scan-over-layers / microbatch / kv-chunk loops is
undercounted by the trip count. This walker re-derives:

  * flops            — dot/convolution ops (2 x numel(out) x K), multiplied
                        by the product of enclosing loop trip counts
                        (``known_trip_count`` backend_config, annotated by
                        XLA's trip-count pass),
  * collective bytes — output payloads of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        trip-multiplied,
  * a flops correction ratio, used to scale the backend's
                        'bytes accessed' (loop-dominated programs: the same
                        multiplier applies to first order; recorded as an
                        approximation in EXPERIMENTS.md).

Dots dominate FLOPs for every cell here; VPU elementwise work is not counted
(consistent across cells, noted in the method).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\]{},]+)\s+"
    r"([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:body|calls|to_apply)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    line: str


_NO_BYTES = {"tuple", "get-tuple-element", "parameter", "bitcast", "constant",
             "after-all", "opt-barrier"}


@dataclasses.dataclass
class WalkResult:
    flops: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    hbm_bytes: float = 0.0


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    current: Optional[str] = None
    for line in hlo.splitlines():
        # computation headers: `%name (args...) -> type {` — args may nest
        # parens (tuple params), so match name + "(" and require "->" ... "{"
        header = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
        if (header and "->" in line and line.rstrip().endswith("{")
                and "=" not in line.split("->")[0].split("(")[0]):
            current = header.group(1)
            comps[current] = []
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[current].append(Op(m.group(1), m.group(2), m.group(3), line))
    return comps


def _dot_flops(op: Op, ops_by_name: Dict[str, Op]) -> float:
    """2 x numel(out) x K; K from the lhs contracting dim."""
    out_n = _numel(op.shape)
    mm = re.search(r"\(([^)]*)\)", op.line[op.line.index(op.opcode):])
    operands = [s.strip().lstrip("%") for s in mm.group(1).split(",")] if mm else []
    k = 1
    dims = _DIMS_RE.search(op.line)
    if operands and dims is not None and dims.group(1):
        lhs = ops_by_name.get(operands[0])
        if lhs is not None:
            sm = _SHAPE_RE.search(lhs.shape)
            if sm:
                shape = [int(d) for d in sm.group(2).split(",") if d]
                for ci in dims.group(1).split(","):
                    ci = int(ci)
                    if ci < len(shape):
                        k *= shape[ci]
    return 2.0 * out_n * k


def _conv_flops(op: Op) -> float:
    # approximation: 2 x numel(out) x (kernel window x in-channels) is not
    # recoverable from the line alone in all cases; use dim_labels if present
    return 2.0 * _numel(op.shape) * 1.0


def walk(hlo: str, entry: Optional[str] = None) -> WalkResult:
    comps = parse_computations(hlo)
    if not comps:
        return WalkResult(0.0, 0.0, {})
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))

    cache: Dict[str, WalkResult] = {}

    def comp_cost(name: str, depth: int = 0) -> WalkResult:
        if name in cache:
            return cache[name]
        if name not in comps or depth > 64:
            return WalkResult(0.0, 0.0, {})
        cache[name] = WalkResult(0.0, 0.0, {})  # cycle guard
        ops = comps[name]
        ops_by_name = {o.name: o for o in ops}
        flops = 0.0
        coll = 0.0
        hbm = 0.0
        by_kind: Dict[str, float] = {}
        for op in ops:
            if op.opcode == "dot":
                flops += _dot_flops(op, ops_by_name)
            elif op.opcode == "convolution":
                flops += _conv_flops(op)
            elif any(op.opcode.startswith(c) for c in _COLLECTIVES):
                if op.opcode.endswith("-done"):
                    continue  # async pair: the -start carries the payload
                b = _shape_bytes(op.shape)
                coll += b
                kind = next(c for c in _COLLECTIVES if op.opcode.startswith(c))
                by_kind[kind] = by_kind.get(kind, 0.0) + b

            # HBM traffic: output + operand bytes per materializing op.
            # Fusions count only their boundary (their body is in-register);
            # while bodies DO materialize per iteration (trip-multiplied).
            if op.opcode not in _NO_BYTES:
                b = _shape_bytes(op.shape)
                mm = re.search(r"\(([^)]*)\)",
                               op.line[op.line.index(op.opcode):])
                if mm:
                    for operand in mm.group(1).split(","):
                        od = ops_by_name.get(operand.strip().lstrip("%"))
                        if od is not None:
                            b += _shape_bytes(od.shape)
                hbm += b

            trip = 1
            if op.opcode == "while":
                t = _TRIP_RE.search(op.line)
                trip = int(t.group(1)) if t else 1
            called = _CALLED_RE.findall(op.line) + _COND_RE.findall(op.line)
            for sub in called:
                sc = comp_cost(sub, depth + 1)
                flops += sc.flops * trip
                coll += sc.coll_bytes * trip
                if op.opcode != "fusion":
                    hbm += sc.hbm_bytes * trip
                for k, v in sc.coll_by_kind.items():
                    by_kind[k] = by_kind.get(k, 0.0) + v * trip
        res = WalkResult(flops, coll, by_kind, hbm)
        cache[name] = res
        return res

    return comp_cost(entry)
