"""Roofline report generation from the dry-run JSON (EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import json
import pathlib
from typing import List

from repro.roofline.analyze import RooflineTerms, from_record, what_moves_it


def load_terms(path: pathlib.Path, mesh: str = "single") -> List[RooflineTerms]:
    recs = json.loads(pathlib.Path(path).read_text())
    out = []
    for r in recs:
        if r.get("status") == "ok" and r.get("mesh") == mesh:
            out.append(from_record(r))
    return out


def format_table(terms: List[RooflineTerms], md: bool = False) -> str:
    lines = []
    if md:
        lines.append("| arch | shape | compute (ms) | memory (ms) | "
                     "collective (ms) | dominant | useful ratio | "
                     "roofline frac | next lever |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
    else:
        lines.append(f"{'arch':22s} {'shape':12s} {'comp ms':>9s} "
                     f"{'mem ms':>9s} {'coll ms':>9s} {'dominant':>10s} "
                     f"{'useful':>7s} {'roofl%':>7s}")
    for t in terms:
        row = (t.arch, t.shape, t.t_compute * 1e3, t.t_memory * 1e3,
               t.t_collective * 1e3, t.dominant, t.useful_ratio,
               100 * t.roofline_fraction)
        if md:
            lines.append("| {} | {} | {:.2f} | {:.2f} | {:.2f} | {} | "
                         "{:.2f} | {:.1f}% | {} |".format(
                             *row, what_moves_it(t)))
        else:
            lines.append("{:22s} {:12s} {:9.2f} {:9.2f} {:9.2f} {:>10s} "
                         "{:7.2f} {:6.1f}%".format(*row))
    return "\n".join(lines)


def print_report(path: pathlib.Path):
    recs = json.loads(pathlib.Path(path).read_text())
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skip"]
    bad = [r for r in recs if r.get("status") not in ("ok", "skip")]
    print(f"\n== Multi-pod dry-run: {len(ok)} compiled, {len(skip)} skipped "
          f"(documented), {len(bad)} failed ==")
    fits = sum(1 for r in ok if r.get("fits_hbm"))
    print(f"HBM (16 GiB/chip, projected-TPU): {fits}/{len(ok)} cells fit")
    for mesh in ("single", "multipod"):
        terms = load_terms(path, mesh)
        if not terms:
            continue
        chips = terms[0].chips
        print(f"\n-- {mesh} mesh ({chips} chips) roofline --")
        print(format_table(terms))
    if bad:
        print("\nFAILED cells:")
        for r in bad:
            print(f"  {r['arch']} {r['shape']} {r['mesh']}: "
                  f"{r.get('error', r.get('status'))[:160]}")


if __name__ == "__main__":
    import sys
    print_report(pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                              else "results/dryrun/all.json"))
