"""jnp interpreter for the op-graph IR.

``evaluate(graph, inputs, params)`` is the *oracle*: plain jnp, no scheduling,
no fusion decisions — exactly the role of the PyTorch reference in the paper's
AI Bench. The same per-op implementations back shape inference.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# per-op jnp implementations
# ----------------------------------------------------------------------

def op_impl(op: str, attrs: Dict[str, Any]) -> Callable:
    """Return a jnp callable implementing ``op`` with the given attrs."""

    a = attrs

    if op == "identity" or op == "dropout":
        return lambda x: x
    if op == "relu":
        return lambda x: jnp.maximum(x, 0)
    if op == "gelu":
        approx = a.get("approximate", True)
        return lambda x: jax.nn.gelu(x, approximate=approx)
    if op in ("silu", "swish"):
        return jax.nn.silu
    if op == "sigmoid":
        return jax.nn.sigmoid
    if op == "tanh":
        return jnp.tanh
    if op == "mish":
        return lambda x: x * jnp.tanh(jax.nn.softplus(x))
    if op == "softplus":
        return jax.nn.softplus
    if op == "exp":
        return jnp.exp
    if op == "abs":
        return jnp.abs
    if op == "square":
        return jnp.square
    if op == "neg":
        return jnp.negative
    if op == "hardtanh":
        lo, hi = a.get("min", -1.0), a.get("max", 1.0)
        return lambda x: jnp.clip(x, lo, hi)
    if op == "leakyrelu":
        slope = a.get("slope", 0.01)
        return lambda x: jnp.where(x >= 0, x, slope * x)

    if op in ("add", "sub", "mul", "div", "minimum", "maximum", "pow"):
        fn = {"add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
              "div": jnp.divide, "minimum": jnp.minimum, "maximum": jnp.maximum,
              "pow": jnp.power}[op]
        return fn

    if op == "scale":
        c = a["value"]
        return lambda x: x * jnp.asarray(c, dtype=x.dtype)
    if op == "add_scalar":
        c = a["value"]
        return lambda x: x + jnp.asarray(c, dtype=x.dtype)
    if op == "clamp_min":
        c = a["value"]
        return lambda x: jnp.maximum(x, jnp.asarray(c, dtype=x.dtype))
    if op == "clamp_max":
        c = a["value"]
        return lambda x: jnp.minimum(x, jnp.asarray(c, dtype=x.dtype))

    if op in ("reduce_sum", "reduce_max", "reduce_min", "reduce_mean", "logsumexp"):
        axes = a.get("axes")
        axes = tuple(axes) if axes is not None else None
        keepdims = a.get("keepdims", False)
        fn = {"reduce_sum": jnp.sum, "reduce_max": jnp.max, "reduce_min": jnp.min,
              "reduce_mean": jnp.mean,
              "logsumexp": jax.scipy.special.logsumexp}[op]
        return lambda x: fn(x, axis=axes, keepdims=keepdims)

    if op == "softmax":
        axis = a.get("axis", -1)
        return lambda x: jax.nn.softmax(x, axis=axis)

    if op == "bias_add":
        return lambda x, b: x + b

    if op == "matmul":
        ta, tb = a.get("transpose_a", False), a.get("transpose_b", False)
        def mm(x, w):
            if ta:
                x = jnp.swapaxes(x, -1, -2)
            if tb:
                w = jnp.swapaxes(w, -1, -2)
            return jnp.matmul(x, w)
        return mm
    if op == "bmm":
        return jnp.matmul

    if op in ("conv2d", "conv3d", "conv_transpose2d", "conv_transpose3d"):
        nd = 2 if "2d" in op else 3
        stride = a.get("stride", 1)
        stride = (stride,) * nd if isinstance(stride, int) else tuple(stride)
        padding = a.get("padding", "SAME")
        if isinstance(padding, int):
            padding = [(padding, padding)] * nd
        layout = a.get("layout", "NCHW" if nd == 2 else "NCDHW")
        # the memory-access stage may request channels-last execution while the
        # graph contract stays NCHW: transpose in, run NHWC, transpose out.
        internal = a.get("internal_layout")
        transpose = "transpose" in op
        # weight layouts follow torch: OIHW for conv, IOHW for conv_transpose
        wspec2 = "IOHW" if transpose else "OIHW"
        wspec3 = "IODHW" if transpose else "OIDHW"
        if nd == 2:
            dn = ("NCHW", wspec2, "NCHW") if layout == "NCHW" else ("NHWC", "HWIO", "NHWC")
            dn_int = ("NHWC", wspec2, "NHWC")
        else:
            dn = ("NCDHW", wspec3, "NCDHW") if layout == "NCDHW" else ("NDHWC", "DHWIO", "NDHWC")
            dn_int = ("NDHWC", wspec3, "NDHWC")

        def conv(x, w):
            use_dn = dn
            perm_in = perm_out = None
            if internal == "NHWC" and layout.startswith("NC"):
                perm_in = (0,) + tuple(range(2, 2 + nd)) + (1,)
                perm_out = (0, nd + 1) + tuple(range(1, 1 + nd))
                x = jnp.transpose(x, perm_in)
                use_dn = dn_int
            dnums = jax.lax.conv_dimension_numbers(x.shape, w.shape, use_dn)
            if transpose:
                out = jax.lax.conv_transpose(
                    x, w, strides=stride, padding=padding, dimension_numbers=use_dn)
            else:
                out = jax.lax.conv_general_dilated(
                    x, w, window_strides=stride, padding=padding,
                    dimension_numbers=dnums)
            if perm_out is not None:
                out = jnp.transpose(out, perm_out)
            return out
        return conv

    if op in ("layernorm", "rmsnorm"):
        eps = a.get("eps", 1e-5)
        rms = op == "rmsnorm"
        elementwise = a.get("elementwise_affine", True)

        def norm(x, *wb):
            ax = -1
            if rms:
                var = jnp.mean(jnp.square(x), axis=ax, keepdims=True)
                y = x * jax.lax.rsqrt(var + eps)
            else:
                mu = jnp.mean(x, axis=ax, keepdims=True)
                var = jnp.var(x, axis=ax, keepdims=True)
                y = (x - mu) * jax.lax.rsqrt(var + eps)
            if elementwise and len(wb) >= 1:
                y = y * wb[0]
            if elementwise and len(wb) >= 2:
                y = y + wb[1]
            return y
        return norm

    if op == "instancenorm":
        eps = a.get("eps", 1e-5)

        def inorm(x):  # NC... : normalize over spatial dims
            axes = tuple(range(2, x.ndim))
            mu = jnp.mean(x, axis=axes, keepdims=True)
            var = jnp.var(x, axis=axes, keepdims=True)
            return (x - mu) * jax.lax.rsqrt(var + eps)
        return inorm

    if op == "batchnorm":
        eps = a.get("eps", 1e-5)

        def bnorm(x, scale, bias, mean, var):  # inference-mode
            shape = (1, -1) + (1,) * (x.ndim - 2)
            return ((x - mean.reshape(shape)) * jax.lax.rsqrt(var.reshape(shape) + eps)
                    * scale.reshape(shape) + bias.reshape(shape))
        return bnorm

    if op == "groupnorm":
        eps = a.get("eps", 1e-5)
        groups = a.get("groups", 8)

        def gnorm(x):  # NC...
            n, c = x.shape[0], x.shape[1]
            rest = x.shape[2:]
            xg = x.reshape((n, groups, c // groups) + rest)
            axes = tuple(range(2, xg.ndim))
            mu = jnp.mean(xg, axis=axes, keepdims=True)
            var = jnp.var(xg, axis=axes, keepdims=True)
            return ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(x.shape)
        return gnorm

    if op in ("avgpool2d", "maxpool2d"):
        k = a.get("kernel", 2)
        k = (k, k) if isinstance(k, int) else tuple(k)
        s = a.get("stride", k)
        s = (s, s) if isinstance(s, int) else tuple(s)
        is_avg = op == "avgpool2d"

        def pool(x):  # NCHW
            window = (1, 1) + k
            strides = (1, 1) + s
            if is_avg:
                out = jax.lax.reduce_window(x, 0.0, jax.lax.add, window, strides, "VALID")
                return out / (k[0] * k[1])
            return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, window, strides, "VALID")
        return pool

    if op == "globalavgpool":
        keepdims = a.get("keepdims", True)
        return lambda x: jnp.mean(x, axis=tuple(range(2, x.ndim)), keepdims=keepdims)

    if op == "transpose":
        perm = tuple(a["perm"])
        return lambda x: jnp.transpose(x, perm)
    if op == "reshape":
        shape = tuple(a["shape"])
        return lambda x: jnp.reshape(x, shape)
    if op == "cast":
        dt = a["dtype"]
        return lambda x: x.astype(jnp.dtype(dt))

    raise ValueError(f"no implementation for op {op!r}")


# ----------------------------------------------------------------------
# parameter materialization + graph evaluation
# ----------------------------------------------------------------------

def make_params(graph, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic parameter init (seeded — the paper's 'identical weights')."""
    out = {}
    key = jax.random.PRNGKey(seed)
    for n in graph.params():
        key, sub = jax.random.split(key)
        init = n.attrs.get("init", "lecun")
        shape, dtype = n.shape, jnp.dtype(n.dtype)
        if init == "ones":
            val = jnp.ones(shape, dtype)
        elif init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "uniform01":
            val = jax.random.uniform(sub, shape, jnp.float32, 0.5, 1.5).astype(dtype)
        else:  # lecun normal on the last dim
            fan_in = shape[-1] if len(shape) >= 1 else 1
            val = (jax.random.normal(sub, shape, jnp.float32)
                   / np.sqrt(max(fan_in, 1))).astype(dtype)
        out[n.name] = val
    return out


def make_inputs(graph, seed: int = 1) -> Dict[str, jnp.ndarray]:
    out = {}
    key = jax.random.PRNGKey(seed)
    for n in graph.inputs():
        key, sub = jax.random.split(key)
        out[n.name] = jax.random.normal(sub, n.shape, jnp.float32).astype(jnp.dtype(n.dtype))
    return out


def evaluate(graph, inputs: Dict[str, jnp.ndarray],
             params: Optional[Dict[str, jnp.ndarray]] = None,
             node_overrides: Optional[Dict[str, Callable]] = None):
    """Evaluate the graph with jnp. Returns dict of output name -> array.

    ``node_overrides`` lets the verifier substitute a real Pallas kernel for a
    node (or fusion group root) while the rest runs the oracle path.
    """
    params = params or {}
    env: Dict[str, jnp.ndarray] = {}
    for n in graph.toposorted():
        if n.op == "input":
            env[n.name] = inputs[n.name]
        elif n.op == "param":
            env[n.name] = params[n.name]
        elif n.op == "const":
            env[n.name] = jnp.asarray(n.attrs["value"], dtype=jnp.dtype(n.dtype))
        else:
            args = [env[i] for i in n.inputs]
            if node_overrides and n.name in node_overrides:
                env[n.name] = node_overrides[n.name](*args)
            else:
                env[n.name] = op_impl(n.op, n.attrs)(*args)
    return {o: env[o] for o in graph.outputs}


def graph_fn(graph, params: Dict[str, jnp.ndarray]):
    """Return fn(inputs_dict) -> outputs dict, suitable for jax.jit."""
    def fn(inputs):
        return evaluate(graph, inputs, params)
    return fn
