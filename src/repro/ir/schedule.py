"""Execution schedule for a kernel program.

A :class:`KernelProgram` = op graph + :class:`Schedule`. The schedule is what
most pipeline stages mutate: how nodes are grouped into kernels (fusion), which
implementation each group uses (XLA / naive Pallas / BlockSpec Pallas), and the
per-kernel :class:`PallasConfig` (tile sizes, grid swizzle, pipeline depth).

Implementation ladder (the paper's before/after axis):
  * ``xla``             — leave the group to the XLA compiler (jnp).
  * ``pallas_naive``    — a Pallas kernel with *manual pointer arithmetic*:
                          flat grid, explicit ``pl.load(ref, (pl.ds(...), ...))``
                          indexing, no BlockSpec tiling → Mosaic cannot pipeline
                          HBM→VMEM copies. The analogue of Triton kernels
                          written without ``tl.make_block_ptr``.
  * ``pallas_blockspec``— BlockSpec-tiled kernel (the "block pointer
                          modernization" target): pipelined, swizzlable.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.ir.graph import Graph

IMPLS = ("xla", "pallas_naive", "pallas_blockspec")


@dataclasses.dataclass
class PallasConfig:
    block_m: int = 128
    block_n: int = 128
    block_k: int = 128
    group_m: int = 1                 # grid swizzle factor (GROUP_SIZE_M analogue)
    num_stages: int = 2              # HBM->VMEM pipeline depth (1 = no overlap)
    dimension_semantics: Tuple[str, ...] = ("parallel", "parallel", "arbitrary")
    acc_dtype: str = "float32"
    persistent: bool = False         # accumulate across grid K-steps in VMEM scratch
    masked: bool = True              # boundary checks on ragged edges
    vmem_budget_frac: float = 0.5

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        d = dict(d)
        if "dimension_semantics" in d:
            d["dimension_semantics"] = tuple(d["dimension_semantics"])
        return cls(**d)


@dataclasses.dataclass
class FusionGroup:
    name: str
    nodes: List[str]                 # topo-ordered node names
    root: str                        # the contraction / dominant op
    impl: str = "xla"
    config: Optional[PallasConfig] = None
    # memory-access attrs the memory stage toggles:
    operand_layouts: Dict[str, str] = dataclasses.field(default_factory=dict)
    prefetch: bool = False

    def to_dict(self):
        return {
            "name": self.name, "nodes": list(self.nodes), "root": self.root,
            "impl": self.impl,
            "config": self.config.to_dict() if self.config else None,
            "operand_layouts": dict(self.operand_layouts),
            "prefetch": self.prefetch,
        }

    @classmethod
    def from_dict(cls, d):
        d = dict(d)
        if d.get("config"):
            d["config"] = PallasConfig.from_dict(d["config"])
        return cls(**d)


@dataclasses.dataclass
class Schedule:
    groups: List[FusionGroup]
    compute_dtype: str = "float32"   # dtype-stage output (bf16 inputs / f32 accum)

    def group_of(self, node_name: str) -> FusionGroup:
        for g in self.groups:
            if node_name in g.nodes:
                return g
        raise KeyError(node_name)

    def validate_against(self, graph: Graph):
        scheduled = [n for g in self.groups for n in g.nodes]
        if len(scheduled) != len(set(scheduled)):
            raise ValueError("node scheduled in more than one group")
        want = {n.name for n in graph.toposorted() if n.op not in ("input", "param", "const")}
        have = set(scheduled)
        if want != have:
            raise ValueError(f"schedule/graph mismatch: missing={want - have} extra={have - want}")

    def copy(self) -> "Schedule":
        return Schedule(
            groups=[FusionGroup.from_dict(g.to_dict()) for g in self.groups],
            compute_dtype=self.compute_dtype,
        )

    def to_dict(self):
        return {"groups": [g.to_dict() for g in self.groups],
                "compute_dtype": self.compute_dtype}

    @classmethod
    def from_dict(cls, d):
        return cls(groups=[FusionGroup.from_dict(g) for g in d["groups"]],
                   compute_dtype=d.get("compute_dtype", "float32"))


def eager_schedule(graph: Graph) -> Schedule:
    """One group per node, XLA impl — the 'eager dispatch' baseline."""
    groups = []
    for n in graph.toposorted():
        if n.op in ("input", "param", "const"):
            continue
        groups.append(FusionGroup(name=f"g_{n.name}", nodes=[n.name], root=n.name))
    return Schedule(groups=groups)


def greedy_fused_schedule(graph: Graph) -> Schedule:
    """Greedy elementwise fusion into producers — the 'compiler' baseline
    (roughly what TorchInductor / XLA fusion achieves without restructuring)."""
    sched = eager_schedule(graph)
    # repeatedly merge single-consumer elementwise nodes into their producer group
    merged = True
    while merged:
        merged = False
        for g in list(sched.groups):
            last = graph.node(g.nodes[-1])
            consumers = graph.consumers(last.name)
            if len(consumers) != 1:
                continue
            c = consumers[0]
            if not (c.is_elementwise() or c.op == "softmax"):
                continue
            # all of c's other inputs must be sources or already-computed group outputs
            cg = sched.group_of(c.name)
            if cg is g or len(cg.nodes) != 1:
                continue
            g.nodes.append(c.name)
            sched.groups.remove(cg)
            merged = True
            break
    return sched


def rename_program(program: "KernelProgram", rename) -> "KernelProgram":
    """Deep-copy ``program`` with every graph node renamed through
    ``rename`` (a ``str`` prefix or a ``name -> new_name`` callable).

    Fingerprints are name-invariant (canonical renaming normalizes names
    away), so the twin shares the original's exact/family/exec/oracle
    fingerprints while carrying entirely different node names. That is the
    shape the cross-job shared verify cache is built for: name-*bound* keys
    (the pre-content-addressing leaf fingerprints) miss across the pair,
    content-addressed and canonical keys hit — making this the standard
    twin-builder for the shared-cache tests and the batch benchmark.

    Nodes are rebuilt in insertion order (the toposort tie-breaks on it,
    so order must survive for canonical forms to stay bit-identical)."""
    from repro.ir.graph import Node

    if isinstance(rename, str):
        prefix = rename
        rename = lambda name, _p=prefix: f"{_p}{name}"
    mapping = {name: rename(name) for name in program.graph.nodes}
    if len(set(mapping.values())) != len(mapping):
        raise ValueError("rename collapsed distinct node names")
    g = Graph(program.graph.name)
    for n in program.graph.nodes.values():
        g.nodes[mapping[n.name]] = Node(
            name=mapping[n.name], op=n.op,
            inputs=[mapping[i] for i in n.inputs],
            attrs=dict(n.attrs), shape=tuple(n.shape), dtype=str(n.dtype))
    g.outputs = [mapping[o] for o in program.graph.outputs]
    g.reseed_counter()
    sched = program.schedule.copy()
    for grp in sched.groups:
        grp.nodes = [mapping[n] for n in grp.nodes]
        grp.root = mapping[grp.root]
        grp.operand_layouts = {mapping.get(k, k): v
                               for k, v in grp.operand_layouts.items()}
    return KernelProgram(name=program.name, graph=g, schedule=sched,
                         original_flops=program.original_flops,
                         meta=dict(program.meta))


@dataclasses.dataclass
class KernelProgram:
    """The unit the pipeline optimizes: graph + schedule (+ provenance)."""

    name: str
    graph: Graph
    schedule: Schedule
    original_flops: float = 0.0      # FLOPs of the *original* graph (paper's accounting)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def copy(self) -> "KernelProgram":
        return KernelProgram(
            name=self.name,
            graph=self.graph.copy(),
            schedule=self.schedule.copy(),
            original_flops=self.original_flops,
            meta=dict(self.meta),
        )

    def validate(self):
        self.schedule.validate_against(self.graph)

    def describe(self) -> str:
        lines = [f"program {self.name} (compute_dtype={self.schedule.compute_dtype})"]
        for g in self.schedule.groups:
            cfg = ""
            if g.config:
                c = g.config
                cfg = (f" cfg(bm={c.block_m},bn={c.block_n},bk={c.block_k},"
                       f"gm={c.group_m},stages={c.num_stages},persist={c.persistent})")
            lines.append(f"  [{g.impl}] {g.name}: {'+'.join(g.nodes)}{cfg}")
        return "\n".join(lines)

    def dumps(self) -> str:
        return json.dumps({
            "name": self.name,
            "schedule": self.schedule.to_dict(),
            "graph_signature": self.graph.signature(),
            "original_flops": self.original_flops,
        }, indent=2)
