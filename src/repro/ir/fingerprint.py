"""Canonical structural fingerprints for kernel programs.

The fleet engine (``repro.core.engine``) keys its result cache on *structure*,
not on names: two :class:`KernelProgram` instances that describe the same
computation under different node names (common across the GEMM family, where
builders differ only in labels) must map to the same cache entry, while any
change to the graph, the schedule, the hardware spec, or the verification
tolerances must change the key.

Canonicalization: nodes are renamed ``n0, n1, ...`` by toposort position (the
toposort prefers insertion order, so renaming alone never perturbs it), ops
and attrs are serialized with sorted keys, and fusion groups are emitted in
schedule order with their node lists mapped through the canonical renaming.
The fingerprint is the sha256 of that canonical form.
"""

from __future__ import annotations

import hashlib
import json
import math
import weakref
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.ir.graph import Graph
from repro.ir.schedule import KernelProgram, Schedule


def canonical_name_map(graph: Graph) -> Dict[str, str]:
    """Map node names to position-based canonical names (``n<topo-index>``)."""
    return {n.name: f"n{i}" for i, n in enumerate(graph.toposorted())}


# Graphs are treated as immutable once built: every pipeline transform copies
# before mutating (see proposers), so a per-object memo is safe. WeakKey so
# discarded candidate graphs don't pin their maps.
_NAME_MAP_CACHE: "weakref.WeakKeyDictionary[Graph, Dict[str, str]]" = \
    weakref.WeakKeyDictionary()


def cached_canonical_name_map(graph: Graph) -> Dict[str, str]:
    """Memoized :func:`canonical_name_map` (one toposort per graph object
    instead of one per call — replay re-canonicalizes every candidate
    description against the same graph)."""
    nm = _NAME_MAP_CACHE.get(graph)
    if nm is None:
        nm = canonical_name_map(graph)
        _NAME_MAP_CACHE[graph] = nm
    return nm


def _canon_attr(value):
    """JSON-stable attr encoding (tuples -> lists, floats kept exact)."""
    if isinstance(value, (list, tuple)):
        return [_canon_attr(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _canon_attr(v) for k, v in sorted(value.items())}
    return value


def graph_canonical(graph: Graph,
                    name_map: Optional[Dict[str, str]] = None) -> List:
    """Name-invariant structural description of a graph."""
    nm = name_map or canonical_name_map(graph)
    nodes = []
    for n in graph.toposorted():
        nodes.append([
            nm[n.name], n.op,
            [nm[i] for i in n.inputs],
            {str(k): _canon_attr(v) for k, v in sorted(n.attrs.items())},
            list(n.shape), str(n.dtype),
        ])
    return [nodes, [nm[o] for o in graph.outputs]]


def schedule_canonical(schedule: Schedule,
                       name_map: Dict[str, str]) -> List:
    """Canonical schedule: groups in schedule order, node lists renamed,
    group names replaced by position (``g<index>``)."""
    groups = []
    for i, grp in enumerate(schedule.groups):
        cfg = grp.config.to_dict() if grp.config else None
        if cfg is not None:
            cfg = {k: _canon_attr(v) for k, v in sorted(cfg.items())}
        groups.append([
            f"g{i}",
            [name_map[n] for n in grp.nodes],
            name_map[grp.root],
            grp.impl,
            cfg,
            {str(k): str(v) for k, v in sorted(grp.operand_layouts.items())},
            bool(grp.prefetch),
        ])
    return [groups, schedule.compute_dtype]


def program_canonical(program: KernelProgram) -> Dict:
    nm = canonical_name_map(program.graph)
    return {
        "graph": graph_canonical(program.graph, nm),
        "schedule": schedule_canonical(program.schedule, nm),
        # meta participates: the analyzer reads it (host_sync, autotuned, ...)
        # so it changes which transforms apply
        "meta": json.loads(json.dumps(program.meta, sort_keys=True,
                                      default=str)),
    }


# ----------------------------------------------------------------------
# Family (near-miss) canonicalization: same builder, different dims.
#
# The exact fingerprint above keys replay — any dim change must miss. The
# *family* form is the transfer key: concrete extents are abstracted to
# symbolic ranks (a shape becomes its rank, dim-lists in attrs become their
# length) and per-kernel tile configs collapse to a presence marker, so a
# GEMM at (4096, 4096, 1024) and the same GEMM at (512, 512, 256) collide.
# A family hit is only ever a *speculative* warm start — every transferred
# step is re-verified on the real shapes — so the abstraction can afford to
# be aggressive.
# ----------------------------------------------------------------------

def _family_attr(value):
    """Dim-abstracted attr encoding: int sequences (target shapes, kernel
    sizes, strides) reduce to their rank; scalars and strings pass through."""
    if isinstance(value, (list, tuple)):
        if value and all(isinstance(v, int) and not isinstance(v, bool)
                         for v in value):
            return ["rank", len(value)]
        return [_family_attr(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _family_attr(v) for k, v in sorted(value.items())}
    return value


def family_canonical(program: KernelProgram) -> Dict:
    """Rank-abstracted structural description: node shapes reduce to their
    rank, attrs lose concrete extents, and Pallas tile configs reduce to a
    presence marker. Two programs from the same builder at different dims
    produce identical family forms."""
    nm = canonical_name_map(program.graph)
    nodes = []
    for n in program.graph.toposorted():
        nodes.append([
            nm[n.name], n.op,
            [nm[i] for i in n.inputs],
            {str(k): _family_attr(v) for k, v in sorted(n.attrs.items())},
            len(n.shape), str(n.dtype),
        ])
    groups = []
    for i, grp in enumerate(program.schedule.groups):
        groups.append([
            f"g{i}",
            [nm[n] for n in grp.nodes],
            nm[grp.root],
            grp.impl,
            grp.config is not None,
            {str(k): str(v) for k, v in sorted(grp.operand_layouts.items())},
            bool(grp.prefetch),
        ])
    return {
        "graph": [nodes, [nm[o] for o in program.graph.outputs]],
        "schedule": [groups, program.schedule.compute_dtype],
        "meta": json.loads(json.dumps(program.meta, sort_keys=True,
                                      default=str)),
    }


def fingerprint_family(ci_program: KernelProgram,
                       bench_program: KernelProgram,
                       spec_name: str,
                       target_dtype: str,
                       tags: Sequence[str] = (),
                       meta: Optional[Dict] = None,
                       policy: str = "") -> str:
    """Transfer key for a job: rank-abstracted structure plus everything that
    scopes the proposer search space (spec, dtype, tags, meta, policy).
    Tolerances deliberately do NOT participate — a transferred log is
    verified step-by-step at the receiving job's own tolerances."""
    payload = {
        "ci": family_canonical(ci_program),
        "bench": family_canonical(bench_program),
        "spec": spec_name,
        "target_dtype": target_dtype,
        "tags": sorted(str(t) for t in tags),
        "meta": json.loads(json.dumps(meta or {}, sort_keys=True,
                                      default=str)),
        "policy": policy,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


# ----------------------------------------------------------------------
# Graded family-key ladder: progressively coarser transfer keys.
#
# The single rank-abstracted family key above treats every same-builder
# neighbor as equally good; the ladder grades the match instead. Three tiers,
# finest first:
#
#   "dims"   — family structure with *concrete* shapes and attr extents:
#              collides only for jobs at identical dims (e.g. the same
#              kernel re-submitted under a different policy signature, or a
#              renamed twin).
#   "aspect" — each shape reduced to its aspect ratio (dims divided by their
#              gcd): a uniformly scaled twin (every dim halved) collides, a
#              reshaped variant does not.
#   "rank"   — exactly :func:`fingerprint_family` (byte-identical, so stores
#              recorded before the ladder existed stay reachable at this
#              tier).
#
# The engine's transfer path walks the tiers finest-to-coarsest and, within
# a tier, ranks neighbors by dim log-distance + transform-log length
# (``repro.core.result_store``).
# ----------------------------------------------------------------------

FAMILY_LADDER_TIERS = ("dims", "aspect", "rank")


def _aspect(shape) -> List[int]:
    """Shape reduced to its aspect ratio: every dim divided by the gcd of
    all dims, so (4096, 1024) and (2048, 512) both map to (4, 1)."""
    dims = [int(d) for d in shape]
    positive = [d for d in dims if d > 0]
    if not positive:
        return dims
    g = positive[0]
    for d in positive[1:]:
        while d:
            g, d = d, g % d
    return [d // g if d > 0 else d for d in dims]


def _tier_canonical(program: KernelProgram, tier: str) -> Dict:
    """Per-tier analogue of :func:`family_canonical`. The "rank" tier IS
    ``family_canonical`` (kept byte-identical for store compatibility);
    "dims" keeps concrete shapes/attr extents, "aspect" normalizes shapes
    to ratios. Non-rank tiers tag the payload with the tier name so a
    scalar-only program can never alias keys across tiers."""
    if tier == "rank":
        return family_canonical(program)
    nm = canonical_name_map(program.graph)
    attr_fn = _canon_attr if tier == "dims" else _family_attr
    nodes = []
    for n in program.graph.toposorted():
        shape = (list(n.shape) if tier == "dims"
                 else ["aspect", _aspect(n.shape)])
        nodes.append([
            nm[n.name], n.op,
            [nm[i] for i in n.inputs],
            {str(k): attr_fn(v) for k, v in sorted(n.attrs.items())},
            shape, str(n.dtype),
        ])
    groups = []
    for i, grp in enumerate(program.schedule.groups):
        groups.append([
            f"g{i}",
            [nm[n] for n in grp.nodes],
            nm[grp.root],
            grp.impl,
            grp.config is not None,
            {str(k): str(v) for k, v in sorted(grp.operand_layouts.items())},
            bool(grp.prefetch),
        ])
    return {
        "tier": tier,
        "graph": [nodes, [nm[o] for o in program.graph.outputs]],
        "schedule": [groups, program.schedule.compute_dtype],
        "meta": json.loads(json.dumps(program.meta, sort_keys=True,
                                      default=str)),
    }


def fingerprint_family_ladder(ci_program: KernelProgram,
                              bench_program: KernelProgram,
                              spec_name: str,
                              target_dtype: str,
                              tags: Sequence[str] = (),
                              meta: Optional[Dict] = None,
                              policy: str = "") -> Tuple[Tuple[str, str], ...]:
    """Ordered ``((tier, key), ...)`` pairs, finest tier first. The last
    pair is always ``("rank", fingerprint_family(...))`` — byte-identical to
    the pre-ladder family key, so entries recorded before the ladder existed
    remain reachable at the coarsest tier."""
    out = []
    for tier in FAMILY_LADDER_TIERS:
        if tier == "rank":
            out.append((tier, fingerprint_family(
                ci_program, bench_program, spec_name, target_dtype, tags,
                meta=meta, policy=policy)))
            continue
        payload = {
            "ci": _tier_canonical(ci_program, tier),
            "bench": _tier_canonical(bench_program, tier),
            "spec": spec_name,
            "target_dtype": target_dtype,
            "tags": sorted(str(t) for t in tags),
            "meta": json.loads(json.dumps(meta or {}, sort_keys=True,
                                          default=str)),
            "policy": policy,
        }
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        out.append((tier, hashlib.sha256(blob.encode()).hexdigest()))
    return tuple(out)


def job_dims_vector(ci_program: KernelProgram,
                    bench_program: KernelProgram) -> Tuple[int, ...]:
    """Concatenated concrete shape extents of both programs' nodes in topo
    order — the rename-invariant coordinate the store's neighbor ranking
    measures dim log-distance in. Same-rank family members produce vectors
    of equal length, so the distance is always well-defined within a tier."""
    dims: List[int] = []
    for prog in (ci_program, bench_program):
        for n in prog.graph.toposorted():
            dims.extend(int(d) for d in n.shape)
    return tuple(dims)


def dims_log_distance(a: Sequence[int], b: Sequence[int]) -> float:
    """Sum of |log(a_i / b_i)| over paired dims — 0.0 for identical dims,
    small for near misses, ``inf`` for unknown/mismatched vectors (entries
    recorded before dims were stored rank last within their tier)."""
    if a is None or b is None or len(a) != len(b):
        return float("inf")
    dist = 0.0
    for x, y in zip(a, b):
        dist += abs(math.log(max(int(x), 1) / max(int(y), 1)))
    return dist


def fingerprint_program(program: KernelProgram,
                        spec_name: str = "",
                        target_dtype: str = "",
                        rtol: float = 0.0,
                        atol: float = 0.0,
                        tags: Sequence[str] = (),
                        meta: Optional[Dict] = None,
                        policy: str = "") -> str:
    """Fingerprint of (graph, schedule, spec, tolerances) — the cache key
    domain of the optimization engine. ``tags`` participate because they
    scope KB pattern applicability and therefore the proposer search space;
    ``meta`` because the analyzer raises issues from it; ``policy`` is the
    driver's configuration signature (stage ablations etc.)."""
    payload = {
        "program": program_canonical(program),
        "spec": spec_name,
        "target_dtype": target_dtype,
        "rtol": repr(float(rtol)),
        "atol": repr(float(atol)),
        "tags": sorted(str(t) for t in tags),
        "meta": json.loads(json.dumps(meta or {}, sort_keys=True,
                                      default=str)),
        "policy": policy,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


# ----------------------------------------------------------------------
# Per-node / per-group fingerprints: the verification fast path's keys.
#
# The exact/family forms above key whole *jobs*; the incremental verifier
# (``repro.core.verify_cache``) needs finer grain — "has this exact subgraph
# slice, fed these exact values, been executed before?". Values are chained
# Merkle-style: a leaf's fingerprint is its binding (inputs/params bind by
# name to the session's seeded arrays; consts by value), a computed node's
# value fingerprint is derived from the fingerprint of the group execution
# that produced it, and a group's fingerprint folds in its local structure
# plus the value fingerprints of every external operand. Mutating one group
# therefore changes its own fingerprint and every downstream one — exactly
# the slice that must re-execute — while untouched upstream groups keep
# their keys and replay from the session cache.
# ----------------------------------------------------------------------

def _hash_payload(payload) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def leaf_fingerprint(node) -> str:
    """Value fingerprint of a graph leaf. Inputs/params bind by *name* to the
    session's fixed seeded arrays (``ProblemContext.ci_inputs``/``ci_params``),
    so the name IS the value identity within a session; consts carry their
    value. Shape/dtype participate so a re-shaped leaf can never alias."""
    if node.op == "const":
        return _hash_payload(["const", repr(node.attrs.get("value")),
                              list(node.shape), str(node.dtype)])
    return _hash_payload([node.op, node.name, list(node.shape),
                          str(node.dtype)])


# Content fingerprints are memoized per array *object*: within a job the
# seeded inputs/params are fixed array instances, and across jobs the shared
# oracle slice hands the very same objects to every consumer, so the byte
# digest is paid once per distinct array. Keyed by id() with a weakref
# liveness guard — id reuse after collection can never serve a stale digest
# because the weakref callback evicts the entry first (and a dead ref is
# re-checked with ``ref() is arr`` regardless).
_ARRAY_FP_CACHE: Dict[int, Tuple[Any, str]] = {}


def array_content_fingerprint(arr) -> str:
    """Content digest of an array: dtype + shape + raw little-endian bytes.
    Two arrays digest equal iff they are bit-identical with the same shape
    and dtype — the value identity the cross-job verification cache keys on
    (:mod:`repro.core.verify_cache`)."""
    key = id(arr)
    hit = _ARRAY_FP_CACHE.get(key)
    if hit is not None and hit[0]() is arr:
        return hit[1]
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(repr(a.shape).encode())
    h.update(a.tobytes())
    fp = h.hexdigest()
    try:
        ref = weakref.ref(arr, lambda _r, _k=key: _ARRAY_FP_CACHE.pop(_k, None))
    except TypeError:
        return fp  # not weakref-able: correct, just unmemoized
    _ARRAY_FP_CACHE[key] = (ref, fp)
    return fp


def content_leaf_fingerprint(node, arr) -> str:
    """Value fingerprint of an input/param leaf bound to an actual array.
    Unlike :func:`leaf_fingerprint` this addresses the *content*, not the
    name: two jobs whose leaves hold bit-identical arrays produce identical
    downstream group keys regardless of which job seeded them — the property
    that lets renamed family twins share one oracle execution."""
    return _hash_payload(["leaf", array_content_fingerprint(arr),
                          list(node.shape), str(node.dtype)])


def graph_oracle_fingerprint(graph: Graph) -> str:
    """Rename-invariant key for shared oracle prep (seeded inputs/params +
    f32 oracle outputs). Canonical-equal graphs seed bit-identical arrays
    positionally: sources drain from the toposort FIFO in insertion order
    before any computed node, ``make_inputs``/``make_params`` iterate that
    same order splitting PRNG keys per position, and names never feed the
    PRNG — so a prep stored as positional lists rebinds exactly to any
    canonical twin's names."""
    return _hash_payload(["oracle", graph_canonical(graph)])


def program_exec_fingerprint(program: KernelProgram) -> str:
    """Rename-invariant digest of everything that determines a program's
    initial verification slice: canonical graph + canonical schedule. Jobs
    with equal digests seed positionally identical arrays and walk identical
    group-execution keys — the batch planner's dedup key
    (:meth:`repro.core.engine.OptimizationEngine.run_batch`)."""
    nm = cached_canonical_name_map(program.graph)
    return _hash_payload([graph_canonical(program.graph, nm),
                          schedule_canonical(program.schedule, nm)])


def group_value_fingerprint(group_fp: str, position: int) -> str:
    """Value fingerprint of the ``position``-th node a group execution
    produced (the chaining link for downstream group keys)."""
    return hashlib.sha256(f"{group_fp}#{position}".encode()).hexdigest()


def group_fingerprint(graph: Graph, group, value_fps: Mapping[str, str],
                      extra=()) -> str:
    """Rename-invariant execution key for one fusion group: the group's
    local structure (ops/attrs/shapes/dtypes, in-group wiring by position)
    plus the value fingerprints of every external operand, plus ``extra``
    (the executor's effective dispatch signature, compute dtype, ...).
    Node *names* never participate — cached outputs are stored positionally
    and rebound to the consuming program's names on reuse."""
    local = {name: i for i, name in enumerate(group.nodes)}
    nodes = []
    for name in group.nodes:
        n = graph.node(name)
        ins = [["loc", local[i]] if i in local else ["ext", value_fps[i]]
               for i in n.inputs]
        nodes.append([n.op, ins,
                      {str(k): _canon_attr(v) for k, v in sorted(n.attrs.items())},
                      list(n.shape), str(n.dtype)])
    return _hash_payload([nodes, list(extra)])


def graph_exact_fingerprint(graph: Graph) -> str:
    """Name-*sensitive* structural digest (names, ops, attrs, shapes,
    dtypes, outputs). Unlike :func:`graph_canonical` this keeps real names —
    it keys caches whose stored values embed names (oracle outputs, verifier
    diagnostics), where a renamed twin must miss."""
    nodes = [[n.name, n.op, list(n.inputs),
              {str(k): _canon_attr(v) for k, v in sorted(n.attrs.items())},
              list(n.shape), str(n.dtype)]
             for n in graph.toposorted()]
    return _hash_payload([nodes, list(graph.outputs)])


def program_exact_fingerprint(program: KernelProgram) -> str:
    """Name-sensitive digest of a whole program (graph + schedule + meta) —
    the session key for memoized cost-model results and structure checks,
    whose messages embed group names."""
    return _hash_payload([
        graph_exact_fingerprint(program.graph),
        program.schedule.to_dict(),
        json.loads(json.dumps(program.meta, sort_keys=True, default=str)),
    ])


def trace_fingerprint(program: KernelProgram) -> str:
    """Key for memoized abstract-trace (``jax.eval_shape``) successes. The
    syntax gate traces with ``use_pallas=False``, so only the graph, the
    group partition (dtype casts happen at group boundaries) and the compute
    dtype can change the outcome — per-group impls/configs are ignored,
    which is what lets config-only candidates skip re-tracing. Rename-
    invariant: only successes are cached and tracing never reads names
    across programs."""
    nm = canonical_name_map(program.graph)
    partition = [[nm[n] for n in grp.nodes]
                 for grp in program.schedule.groups]
    return _hash_payload([graph_canonical(program.graph, nm), partition,
                          program.schedule.compute_dtype])


def fingerprint_job(ci_program: KernelProgram,
                    bench_program: KernelProgram,
                    spec_name: str,
                    target_dtype: str,
                    rtol: float,
                    atol: float,
                    tags: Sequence[str] = (),
                    meta: Optional[Dict] = None,
                    policy: str = "") -> str:
    """Cache key for a full optimization job: both the ci-shaped and the
    bench-shaped programs participate (the pipeline verifies on ci shapes and
    scores on bench shapes, so either differing must miss)."""
    parts = [
        fingerprint_program(ci_program, spec_name, target_dtype, rtol, atol,
                            tags, meta, policy),
        fingerprint_program(bench_program, spec_name, target_dtype, rtol,
                            atol, tags, meta, policy),
    ]
    return hashlib.sha256("|".join(parts).encode()).hexdigest()
