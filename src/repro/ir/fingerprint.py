"""Canonical structural fingerprints for kernel programs.

The fleet engine (``repro.core.engine``) keys its result cache on *structure*,
not on names: two :class:`KernelProgram` instances that describe the same
computation under different node names (common across the GEMM family, where
builders differ only in labels) must map to the same cache entry, while any
change to the graph, the schedule, the hardware spec, or the verification
tolerances must change the key.

Canonicalization: nodes are renamed ``n0, n1, ...`` by toposort position (the
toposort prefers insertion order, so renaming alone never perturbs it), ops
and attrs are serialized with sorted keys, and fusion groups are emitted in
schedule order with their node lists mapped through the canonical renaming.
The fingerprint is the sha256 of that canonical form.
"""

from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Optional, Sequence

from repro.ir.graph import Graph
from repro.ir.schedule import KernelProgram, Schedule


def canonical_name_map(graph: Graph) -> Dict[str, str]:
    """Map node names to position-based canonical names (``n<topo-index>``)."""
    return {n.name: f"n{i}" for i, n in enumerate(graph.toposorted())}


def _canon_attr(value):
    """JSON-stable attr encoding (tuples -> lists, floats kept exact)."""
    if isinstance(value, (list, tuple)):
        return [_canon_attr(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _canon_attr(v) for k, v in sorted(value.items())}
    return value


def graph_canonical(graph: Graph,
                    name_map: Optional[Dict[str, str]] = None) -> List:
    """Name-invariant structural description of a graph."""
    nm = name_map or canonical_name_map(graph)
    nodes = []
    for n in graph.toposorted():
        nodes.append([
            nm[n.name], n.op,
            [nm[i] for i in n.inputs],
            {str(k): _canon_attr(v) for k, v in sorted(n.attrs.items())},
            list(n.shape), str(n.dtype),
        ])
    return [nodes, [nm[o] for o in graph.outputs]]


def schedule_canonical(schedule: Schedule,
                       name_map: Dict[str, str]) -> List:
    """Canonical schedule: groups in schedule order, node lists renamed,
    group names replaced by position (``g<index>``)."""
    groups = []
    for i, grp in enumerate(schedule.groups):
        cfg = grp.config.to_dict() if grp.config else None
        if cfg is not None:
            cfg = {k: _canon_attr(v) for k, v in sorted(cfg.items())}
        groups.append([
            f"g{i}",
            [name_map[n] for n in grp.nodes],
            name_map[grp.root],
            grp.impl,
            cfg,
            {str(k): str(v) for k, v in sorted(grp.operand_layouts.items())},
            bool(grp.prefetch),
        ])
    return [groups, schedule.compute_dtype]


def program_canonical(program: KernelProgram) -> Dict:
    nm = canonical_name_map(program.graph)
    return {
        "graph": graph_canonical(program.graph, nm),
        "schedule": schedule_canonical(program.schedule, nm),
        # meta participates: the analyzer reads it (host_sync, autotuned, ...)
        # so it changes which transforms apply
        "meta": json.loads(json.dumps(program.meta, sort_keys=True,
                                      default=str)),
    }


# ----------------------------------------------------------------------
# Family (near-miss) canonicalization: same builder, different dims.
#
# The exact fingerprint above keys replay — any dim change must miss. The
# *family* form is the transfer key: concrete extents are abstracted to
# symbolic ranks (a shape becomes its rank, dim-lists in attrs become their
# length) and per-kernel tile configs collapse to a presence marker, so a
# GEMM at (4096, 4096, 1024) and the same GEMM at (512, 512, 256) collide.
# A family hit is only ever a *speculative* warm start — every transferred
# step is re-verified on the real shapes — so the abstraction can afford to
# be aggressive.
# ----------------------------------------------------------------------

def _family_attr(value):
    """Dim-abstracted attr encoding: int sequences (target shapes, kernel
    sizes, strides) reduce to their rank; scalars and strings pass through."""
    if isinstance(value, (list, tuple)):
        if value and all(isinstance(v, int) and not isinstance(v, bool)
                         for v in value):
            return ["rank", len(value)]
        return [_family_attr(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _family_attr(v) for k, v in sorted(value.items())}
    return value


def family_canonical(program: KernelProgram) -> Dict:
    """Rank-abstracted structural description: node shapes reduce to their
    rank, attrs lose concrete extents, and Pallas tile configs reduce to a
    presence marker. Two programs from the same builder at different dims
    produce identical family forms."""
    nm = canonical_name_map(program.graph)
    nodes = []
    for n in program.graph.toposorted():
        nodes.append([
            nm[n.name], n.op,
            [nm[i] for i in n.inputs],
            {str(k): _family_attr(v) for k, v in sorted(n.attrs.items())},
            len(n.shape), str(n.dtype),
        ])
    groups = []
    for i, grp in enumerate(program.schedule.groups):
        groups.append([
            f"g{i}",
            [nm[n] for n in grp.nodes],
            nm[grp.root],
            grp.impl,
            grp.config is not None,
            {str(k): str(v) for k, v in sorted(grp.operand_layouts.items())},
            bool(grp.prefetch),
        ])
    return {
        "graph": [nodes, [nm[o] for o in program.graph.outputs]],
        "schedule": [groups, program.schedule.compute_dtype],
        "meta": json.loads(json.dumps(program.meta, sort_keys=True,
                                      default=str)),
    }


def fingerprint_family(ci_program: KernelProgram,
                       bench_program: KernelProgram,
                       spec_name: str,
                       target_dtype: str,
                       tags: Sequence[str] = (),
                       meta: Optional[Dict] = None,
                       policy: str = "") -> str:
    """Transfer key for a job: rank-abstracted structure plus everything that
    scopes the proposer search space (spec, dtype, tags, meta, policy).
    Tolerances deliberately do NOT participate — a transferred log is
    verified step-by-step at the receiving job's own tolerances."""
    payload = {
        "ci": family_canonical(ci_program),
        "bench": family_canonical(bench_program),
        "spec": spec_name,
        "target_dtype": target_dtype,
        "tags": sorted(str(t) for t in tags),
        "meta": json.loads(json.dumps(meta or {}, sort_keys=True,
                                      default=str)),
        "policy": policy,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def fingerprint_program(program: KernelProgram,
                        spec_name: str = "",
                        target_dtype: str = "",
                        rtol: float = 0.0,
                        atol: float = 0.0,
                        tags: Sequence[str] = (),
                        meta: Optional[Dict] = None,
                        policy: str = "") -> str:
    """Fingerprint of (graph, schedule, spec, tolerances) — the cache key
    domain of the optimization engine. ``tags`` participate because they
    scope KB pattern applicability and therefore the proposer search space;
    ``meta`` because the analyzer raises issues from it; ``policy`` is the
    driver's configuration signature (stage ablations etc.)."""
    payload = {
        "program": program_canonical(program),
        "spec": spec_name,
        "target_dtype": target_dtype,
        "rtol": repr(float(rtol)),
        "atol": repr(float(atol)),
        "tags": sorted(str(t) for t in tags),
        "meta": json.loads(json.dumps(meta or {}, sort_keys=True,
                                      default=str)),
        "policy": policy,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def fingerprint_job(ci_program: KernelProgram,
                    bench_program: KernelProgram,
                    spec_name: str,
                    target_dtype: str,
                    rtol: float,
                    atol: float,
                    tags: Sequence[str] = (),
                    meta: Optional[Dict] = None,
                    policy: str = "") -> str:
    """Cache key for a full optimization job: both the ci-shaped and the
    bench-shaped programs participate (the pipeline verifies on ci shapes and
    scores on bench shapes, so either differing must miss)."""
    parts = [
        fingerprint_program(ci_program, spec_name, target_dtype, rtol, atol,
                            tags, meta, policy),
        fingerprint_program(bench_program, spec_name, target_dtype, rtol,
                            atol, tags, meta, policy),
    ]
    return hashlib.sha256("|".join(parts).encode()).hexdigest()
