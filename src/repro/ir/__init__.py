from repro.ir.graph import Node, Graph, GraphBuilder
from repro.ir.interpreter import evaluate, make_params, op_impl
from repro.ir.cost import node_flops_bytes, CostModel, GroupCost
from repro.ir.fingerprint import (canonical_name_map, fingerprint_job,
                                  fingerprint_program, program_canonical)
from repro.ir.schedule import Schedule, FusionGroup, PallasConfig, KernelProgram

__all__ = [
    "canonical_name_map",
    "fingerprint_job",
    "fingerprint_program",
    "program_canonical",
    "Node",
    "Graph",
    "GraphBuilder",
    "evaluate",
    "make_params",
    "op_impl",
    "node_flops_bytes",
    "CostModel",
    "GroupCost",
    "Schedule",
    "FusionGroup",
    "PallasConfig",
    "KernelProgram",
]
