"""Graph rewrite engine + the algebraic rule bank.

Each rule is a pure function ``rule(graph) -> list[Rewrite]`` where a
:class:`Rewrite` knows how to apply itself to a *copy* of the graph. Rules are
the deterministic stand-in for the paper's LLM "algorithmic optimization" and
"discovery" proposals; every rule the paper names is here:

  * ``matmul_reduce_to_vecmat`` — the paper's Discovery example
    ``sum(x @ W.T, dim=1) -> x @ W.sum(dim=0)``: eliminates an O(MNK) GEMM.
  * ``fold_scale_into_weights`` — caching weight statistics / scalar folding.
  * ``fold_bn_into_conv``       — inference BN folding.
  * plus CSE, cast/transpose/identity cleanup, mean->cheap, tree reductions.

Each rewrite is annotated with validity reasoning (the paper requires the
discovery proposal to state *why* the transformation is mathematically valid);
verification is still enforced downstream by the CoVeR cascade.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.ir.graph import Graph, Node


@dataclasses.dataclass
class Rewrite:
    rule: str
    description: str
    why_valid: str
    estimated_speedup: str
    apply: Callable[[Graph], Graph]

    def __repr__(self):
        return f"Rewrite({self.rule}: {self.description})"


RULES: Dict[str, Callable[[Graph], List[Rewrite]]] = {}


def rule(name):
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


def find_rewrites(graph: Graph, rules: Optional[List[str]] = None) -> List[Rewrite]:
    out = []
    for name, fn in RULES.items():
        if rules is not None and name not in rules:
            continue
        out.extend(fn(graph))
    return out


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------

def _single_consumer(g: Graph, name: str) -> Optional[Node]:
    cons = g.consumers(name)
    if len(cons) == 1 and name not in g.outputs:
        return cons[0]
    return None


# ----------------------------------------------------------------------
# rules
# ----------------------------------------------------------------------

@rule("matmul_reduce_to_vecmat")
def _matmul_reduce(g: Graph) -> List[Rewrite]:
    """sum(A @ B, axis=last) == A @ sum(B, axis=last-of-B);
    sum(A @ B, axis=first-of-out) == sum(A, axis=M) @ B.
    Eliminates an O(MNK) GEMM in favour of O(NK)+O(MK)."""
    out = []
    for n in g.toposorted():
        if n.op != "matmul" or len(n.shape) != 2:
            continue
        red = _single_consumer(g, n.name)
        if red is None or red.op != "reduce_sum":
            continue
        axes = red.attrs.get("axes")
        if axes is None:
            continue
        axes = tuple(ax % 2 for ax in axes)
        if axes not in ((1,), (0,)):
            continue
        mm, rd = n.name, red.name
        reduce_n = axes == (1,)
        keepdims = red.attrs.get("keepdims", False)

        def apply(graph: Graph, mm=mm, rd=rd, reduce_n=reduce_n, keepdims=keepdims) -> Graph:
            g2 = graph.copy()
            node = g2.node(mm)
            a, b = node.inputs
            ta = node.attrs.get("transpose_a", False)
            tb = node.attrs.get("transpose_b", False)
            if reduce_n:
                # sum_n (A@B)[m,n] = Σ_k A[m,k] Σ_n B[k,n]
                b_axis = 0 if tb else 1
                bsum = g2.add("reduce_sum", (b,), axes=(b_axis,), keepdims=True)
                new = g2.add("matmul", (a, bsum), transpose_a=ta,
                             transpose_b=tb)
                res = g2.add("reshape", (new,),
                             shape=g2.node(rd).shape)
            else:
                a_axis = 1 if ta else 0
                asum = g2.add("reduce_sum", (a,), axes=(a_axis,), keepdims=True)
                new = g2.add("matmul", (asum, b), transpose_a=ta, transpose_b=tb)
                res = g2.add("reshape", (new,), shape=g2.node(rd).shape)
            g2.redirect(rd, res)
            g2.dce()
            return g2

        which = "N" if reduce_n else "M"
        out.append(Rewrite(
            rule="matmul_reduce_to_vecmat",
            description=f"eliminate GEMM {mm}: sum over {which} → pre-reduce operand",
            why_valid="Σ_n Σ_k A[m,k]B[k,n] = Σ_k A[m,k](Σ_n B[k,n]); linearity of matmul",
            estimated_speedup="5-100x (removes O(MNK) work)",
            apply=apply,
        ))
    return out


@rule("fold_scale_into_weights")
def _fold_scale(g: Graph) -> List[Rewrite]:
    """(x @ W) * c  ->  x @ (W * c): pre-scale the weight once (cached stat)."""
    out = []
    for n in g.toposorted():
        if n.op not in ("matmul", "conv2d", "conv3d"):
            continue
        cons = _single_consumer(g, n.name)
        if cons is None:
            continue
        scale_val = None
        if cons.op == "scale":
            scale_val = cons.attrs["value"]
        elif cons.op == "div":
            other = [i for i in cons.inputs if i != n.name]
            if len(other) == 1 and g.node(other[0]).op == "const":
                scale_val = 1.0 / g.node(other[0]).attrs["value"]
        elif cons.op == "mul":
            other = [i for i in cons.inputs if i != n.name]
            if len(other) == 1 and g.node(other[0]).op == "const":
                scale_val = g.node(other[0]).attrs["value"]
        if scale_val is None:
            continue
        w = n.inputs[1]
        if g.node(w).op != "param":
            continue
        mm, cn = n.name, cons.name

        def apply(graph: Graph, mm=mm, cn=cn, w=w, scale_val=scale_val) -> Graph:
            g2 = graph.copy()
            ws = g2.add("scale", (w,), value=scale_val)
            g2.replace_input(mm, w, ws)
            g2.redirect(cn, mm)
            g2.dce()
            return g2

        out.append(Rewrite(
            rule="fold_scale_into_weights",
            description=f"fold scalar x{scale_val} after {mm} into weights (cached)",
            why_valid="(xW)c = x(Wc); weight pre-scaling is computed once, amortized",
            estimated_speedup="1.1-2x (removes a full-tensor pass)",
            apply=apply,
        ))
    return out


@rule("fold_bn_into_conv")
def _fold_bn(g: Graph) -> List[Rewrite]:
    """conv -> batchnorm (inference) folds into the conv weights/bias."""
    out = []
    for n in g.toposorted():
        if n.op not in ("conv2d", "conv3d"):
            continue
        bn = _single_consumer(g, n.name)
        if bn is None or bn.op != "batchnorm":
            continue
        conv, bnn = n.name, bn.name

        def apply(graph: Graph, conv=conv, bnn=bnn) -> Graph:
            g2 = graph.copy()
            cnode = g2.node(conv)
            bnode = g2.node(bnn)
            w = cnode.inputs[1]
            scale, bias, mean, var = bnode.inputs[1:5]
            eps = bnode.attrs.get("eps", 1e-5)
            # s = scale / sqrt(var + eps); W' = W * s[:,None,...]; b' = bias - mean*s
            veps = g2.add("add_scalar", (var,), value=eps)
            rsq = g2.add("pow", (veps, g2.add("const", (), value=-0.5, dtype=g2.node(var).dtype)))
            s = g2.add("mul", (scale, rsq))
            wshape = g2.node(w).shape
            srs = g2.add("reshape", (s,), shape=(wshape[0],) + (1,) * (len(wshape) - 1))
            wf = g2.add("mul", (w, srs))
            g2.replace_input(conv, w, wf)
            ms = g2.add("mul", (mean, s))
            bf = g2.add("sub", (bias, ms))
            cshape = g2.node(conv).shape
            brs = g2.add("reshape", (bf,), shape=(1, cshape[1]) + (1,) * (len(cshape) - 2))
            newout = g2.add("add", (conv, brs))
            g2.redirect(bnn, newout)
            # redirect created a self-loop risk: newout consumes conv; fix ordering is fine
            g2.node(newout).inputs = [conv, brs]
            g2.dce()
            return g2

        out.append(Rewrite(
            rule="fold_bn_into_conv",
            description=f"fold inference batchnorm {bnn} into conv {conv}",
            why_valid="BN(x*W) with fixed stats is an affine map; compose with conv weights",
            estimated_speedup="1.2-1.5x (removes a normalization pass)",
            apply=apply,
        ))
    return out


@rule("eliminate_identities")
def _elim_identity(g: Graph) -> List[Rewrite]:
    """drop dropout(inference)/identity, x*1, x+0, double-cast."""
    victims = []
    for n in g.toposorted():
        if n.op in ("identity", "dropout"):
            victims.append((n.name, n.inputs[0]))
        elif n.op == "scale" and float(n.attrs.get("value", 1.0)) == 1.0:
            victims.append((n.name, n.inputs[0]))
        elif n.op == "add_scalar" and float(n.attrs.get("value", 0.0)) == 0.0:
            victims.append((n.name, n.inputs[0]))
        elif n.op == "cast":
            src = g.node(n.inputs[0])
            if src.op == "cast" and src.dtype == n.dtype:
                victims.append((n.name, src.inputs[0]))
            elif src.dtype == n.dtype:
                victims.append((n.name, n.inputs[0]))
    if not victims:
        return []

    def apply(graph: Graph, victims=tuple(victims)) -> Graph:
        g2 = graph.copy()
        for name, repl in victims:
            if name in g2.nodes:
                g2.redirect(name, repl)
        g2.dce()
        return g2

    return [Rewrite(
        rule="eliminate_identities",
        description=f"remove {len(victims)} no-op node(s): "
                     + ",".join(v[0] for v in victims),
        why_valid="identity/no-op elimination preserves values exactly",
        estimated_speedup="1.05-1.3x (launch + traffic)",
        apply=apply,
    )]


@rule("cse")
def _cse(g: Graph) -> List[Rewrite]:
    """common sub-expression elimination."""
    seen: Dict[str, str] = {}
    merges = []
    for n in g.toposorted():
        if n.op in ("input", "param", "const"):
            continue
        key = f"{n.op}|{tuple(n.inputs)}|{sorted(n.attrs.items())!r}"
        if key in seen:
            merges.append((n.name, seen[key]))
        else:
            seen[key] = n.name
    if not merges:
        return []

    def apply(graph: Graph, merges=tuple(merges)) -> Graph:
        g2 = graph.copy()
        for dup, keep in merges:
            if dup in g2.nodes:
                g2.redirect(dup, keep)
        g2.dce()
        return g2

    return [Rewrite(
        rule="cse",
        description=f"merge {len(merges)} duplicated subexpression(s)",
        why_valid="pure ops with identical inputs/attrs compute identical values",
        estimated_speedup="up to 2x on duplicated chains",
        apply=apply,
    )]


@rule("mean_to_sum_scale")
def _mean_to_sum(g: Graph) -> List[Rewrite]:
    """reduce_mean -> reduce_sum * (1/n): exposes the sum to matmul folding."""
    out = []
    for n in g.toposorted():
        if n.op != "reduce_mean":
            continue
        axes = n.attrs.get("axes")
        if axes is None:
            continue
        src_shape = g.node(n.inputs[0]).shape
        cnt = 1
        for ax in axes:
            cnt *= src_shape[ax % len(src_shape)]
        name = n.name

        def apply(graph: Graph, name=name, cnt=cnt) -> Graph:
            g2 = graph.copy()
            node = g2.node(name)
            s = g2.add("reduce_sum", tuple(node.inputs), axes=tuple(node.attrs["axes"]),
                       keepdims=node.attrs.get("keepdims", False))
            sc = g2.add("scale", (s,), value=1.0 / cnt)
            g2.redirect(name, sc)
            g2.dce()
            return g2

        out.append(Rewrite(
            rule="mean_to_sum_scale",
            description=f"canonicalize {name}: mean → sum x (1/{cnt})",
            why_valid="mean(x) = sum(x)/n exactly (fp reassociation within tolerance)",
            estimated_speedup="enables matmul_reduce_to_vecmat",
            apply=apply,
        ))
    return out


@rule("tree_reduction")
def _tree_reduction(g: Graph) -> List[Rewrite]:
    """Mark serial-accumulation reductions for tree (pairwise) reduction.
    jnp reductions are already tree-based; this targets graphs whose producer
    annotated ``accumulate='serial'`` (KernelFalcon-style generated code)."""
    out = []
    for n in g.toposorted():
        if n.op.startswith("reduce_") and n.attrs.get("accumulate") == "serial":
            name = n.name

            def apply(graph: Graph, name=name) -> Graph:
                g2 = graph.copy()
                g2.node(name).attrs["accumulate"] = "tree"
                return g2

            out.append(Rewrite(
                rule="tree_reduction",
                description=f"serial accumulation → tree reduction on {name}",
                why_valid="addition reassociation (within fp tolerance)",
                estimated_speedup="1.2-4x on long reductions",
                apply=apply,
            ))
    return out


@rule("transpose_elimination")
def _transpose_elim(g: Graph) -> List[Rewrite]:
    """transpose(transpose(x)) -> x; transpose feeding matmul -> transpose flag."""
    out = []
    for n in g.toposorted():
        if n.op != "transpose":
            continue
        src = g.node(n.inputs[0])
        if src.op == "transpose":
            p1, p2 = src.attrs["perm"], n.attrs["perm"]
            if [p1[i] for i in p2] == list(range(len(p1))):
                name, repl = n.name, src.inputs[0]

                def apply(graph: Graph, name=name, repl=repl) -> Graph:
                    g2 = graph.copy()
                    g2.redirect(name, repl)
                    g2.dce()
                    return g2

                out.append(Rewrite(
                    rule="transpose_elimination",
                    description=f"cancel transpose pair at {name}",
                    why_valid="P∘P⁻¹ = id",
                    estimated_speedup="removes two layout passes",
                    apply=apply,
                ))
        elif len(n.shape) == 2 and n.attrs.get("perm") in ([1, 0], (1, 0)):
            for c in g.consumers(n.name):
                if c.op == "matmul":
                    idx = c.inputs.index(n.name)
                    cname, tname, src0 = c.name, n.name, n.inputs[0]

                    def apply(graph: Graph, cname=cname, tname=tname, src0=src0,
                              idx=idx) -> Graph:
                        g2 = graph.copy()
                        key = "transpose_a" if idx == 0 else "transpose_b"
                        g2.node(cname).attrs[key] = not g2.node(cname).attrs.get(key, False)
                        g2.replace_input(cname, tname, src0)
                        g2.dce()
                        return g2

                    out.append(Rewrite(
                        rule="transpose_elimination",
                        description=f"absorb transpose {tname} into matmul {cname} flag",
                        why_valid="matmul supports implicit operand transposition",
                        estimated_speedup="removes a materialized transpose",
                        apply=apply,
                    ))
    return out
