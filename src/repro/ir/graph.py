"""Tiny op-graph IR.

The optimization pipeline needs a structured representation of "the kernel" so
that stage transformations are verifiable program rewrites rather than string
edits (our deterministic stand-in for the paper's LLM-edited Triton source).
A :class:`Graph` is a DAG of :class:`Node` ops; shapes/dtypes are inferred
eagerly via ``jax.eval_shape`` over each op's jnp implementation so the IR can
never hold a shape the interpreter would disagree with.

Ops are deliberately KernelBench-Level-2-shaped: matmul/conv + elementwise
chains + reductions + norms + pooling.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Ops with no tensor inputs.
SOURCE_OPS = ("input", "param", "const")

ELEMENTWISE_UNARY = (
    "relu", "gelu", "silu", "swish", "sigmoid", "tanh", "mish", "exp",
    "abs", "square", "neg", "softplus", "hardtanh", "leakyrelu", "identity",
    "dropout",  # inference-mode: identity (kept so the analyzer can flag it)
)
ELEMENTWISE_BINARY = ("add", "sub", "mul", "div", "minimum", "maximum", "pow")
ELEMENTWISE_SCALAR = ("scale", "add_scalar", "clamp_min", "clamp_max")
REDUCTIONS = ("reduce_sum", "reduce_max", "reduce_min", "reduce_mean", "logsumexp")
NORMS = ("layernorm", "rmsnorm", "instancenorm", "batchnorm", "groupnorm")
CONTRACTIONS = ("matmul", "bmm", "conv2d", "conv3d", "conv_transpose2d", "conv_transpose3d")
SHAPE_OPS = ("transpose", "reshape", "cast", "softmax", "avgpool2d", "maxpool2d",
             "globalavgpool", "bias_add")

ALL_OPS = (SOURCE_OPS + ELEMENTWISE_UNARY + ELEMENTWISE_BINARY + ELEMENTWISE_SCALAR
           + REDUCTIONS + NORMS + CONTRACTIONS + SHAPE_OPS)


@dataclasses.dataclass
class Node:
    name: str
    op: str
    inputs: List[str]
    attrs: Dict[str, Any]
    shape: Tuple[int, ...]
    dtype: str

    def is_elementwise(self) -> bool:
        return (self.op in ELEMENTWISE_UNARY or self.op in ELEMENTWISE_BINARY
                or self.op in ELEMENTWISE_SCALAR or self.op in ("bias_add", "cast"))

    def is_contraction(self) -> bool:
        return self.op in CONTRACTIONS

    def is_reduction(self) -> bool:
        return self.op in REDUCTIONS or self.op in ("softmax", "globalavgpool",
                                                    "avgpool2d", "maxpool2d") or self.op in NORMS


class Graph:
    """A DAG of named nodes in insertion (topological) order."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.outputs: List[str] = []
        self._counter = itertools.count()

    # ------------------------------------------------------------------
    def add(self, op: str, inputs: Sequence[str] = (), name: Optional[str] = None,
            **attrs) -> str:
        if op not in ALL_OPS:
            raise ValueError(f"unknown op {op!r}")
        for i in inputs:
            if i not in self.nodes:
                raise KeyError(f"input {i!r} not in graph")
        name = name or f"{op}_{next(self._counter)}"
        if name in self.nodes:
            raise KeyError(f"duplicate node name {name!r}")
        shape, dtype = _infer(self, op, list(inputs), attrs)
        self.nodes[name] = Node(name, op, list(inputs), dict(attrs), shape, dtype)
        return name

    def node(self, name: str) -> Node:
        return self.nodes[name]

    def set_outputs(self, names: Sequence[str]):
        for n in names:
            if n not in self.nodes:
                raise KeyError(n)
        self.outputs = list(names)

    # ------------------------------------------------------------------
    def inputs(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.op == "input"]

    def params(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.op == "param"]

    def consumers(self, name: str) -> List[Node]:
        return [n for n in self.nodes.values() if name in n.inputs]

    def toposorted(self) -> List[Node]:
        """Kahn toposort, preferring insertion order (rewrites like
        ``redirect`` can make insertion order non-topological)."""
        order = list(self.nodes)
        indeg = {k: 0 for k in order}
        for n in self.nodes.values():
            for i in n.inputs:
                indeg[n.name] += 1
        ready = [k for k in order if indeg[k] == 0]
        out: List[Node] = []
        while ready:
            cur = ready.pop(0)
            out.append(self.nodes[cur])
            for c in order:
                n = self.nodes[c]
                if cur in n.inputs:
                    indeg[c] -= n.inputs.count(cur)
                    if indeg[c] == 0:
                        ready.append(c)
        if len(out) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return out

    # ------------------------------------------------------------------
    def replace_input(self, node_name: str, old: str, new: str):
        n = self.nodes[node_name]
        n.inputs = [new if i == old else i for i in n.inputs]

    def redirect(self, old: str, new: str):
        """Point every consumer of ``old`` (and the output list) at ``new``."""
        for n in self.nodes.values():
            if old in n.inputs:
                self.replace_input(n.name, old, new)
        self.outputs = [new if o == old else o for o in self.outputs]

    def dce(self):
        """Remove nodes not reachable from the outputs."""
        live = set()
        stack = list(self.outputs)
        while stack:
            cur = stack.pop()
            if cur in live:
                continue
            live.add(cur)
            stack.extend(self.nodes[cur].inputs)
        self.nodes = {k: v for k, v in self.nodes.items() if k in live}

    def copy(self) -> "Graph":
        g = Graph(self.name)
        g.nodes = {k: Node(v.name, v.op, list(v.inputs), dict(v.attrs), v.shape, v.dtype)
                   for k, v in self.nodes.items()}
        g.outputs = list(self.outputs)
        g.reseed_counter()
        return g

    def reseed_counter(self):
        """Advance the fresh-name counter past every numeric suffix already
        present, so later ``add`` calls never collide with existing names.
        Used after any node-for-node reconstruction (``copy``, the job
        codec's ``decode_graph``)."""
        self._counter = itertools.count(
            max((int(k.rsplit("_", 1)[1]) + 1 for k in self.nodes
                 if "_" in k and k.rsplit("_", 1)[1].isdigit()), default=0))

    def signature(self) -> str:
        parts = [f"{n.name}:{n.op}({','.join(n.inputs)}){n.shape}{n.dtype}"
                 for n in self.toposorted()]
        return ";".join(parts) + "->" + ",".join(self.outputs)

    def __repr__(self):
        return f"Graph({self.name}, {len(self.nodes)} nodes, outputs={self.outputs})"


class GraphBuilder:
    """Convenience builder: ``b = GraphBuilder('p'); x = b.input((M,K)); ...``"""

    def __init__(self, name: str = "graph", dtype: str = "float32"):
        self.g = Graph(name)
        self.default_dtype = dtype

    def input(self, shape, dtype=None, name=None) -> str:
        return self.g.add("input", (), name=name, shape=tuple(shape),
                          dtype=dtype or self.default_dtype)

    def param(self, shape, dtype=None, name=None, init="lecun") -> str:
        return self.g.add("param", (), name=name, shape=tuple(shape),
                          dtype=dtype or self.default_dtype, init=init)

    def const(self, value, name=None, dtype=None) -> str:
        return self.g.add("const", (), name=name, value=float(value),
                          dtype=dtype or self.default_dtype)

    def __getattr__(self, op):
        if op in ALL_OPS:
            def method(*inputs, name=None, **attrs):
                return self.g.add(op, inputs, name=name, **attrs)
            return method
        raise AttributeError(op)

    def done(self, *outputs) -> Graph:
        self.g.set_outputs(list(outputs))
        return self.g


def retype_graph(graph: Graph, dtype_map) -> Graph:
    """Rebuild a graph with source dtypes remapped (e.g. float64 -> float32);
    downstream dtypes re-infer automatically. ``dtype_map`` is a callable
    old_dtype_str -> new_dtype_str."""
    g2 = Graph(graph.name)
    for n in graph.toposorted():
        attrs = dict(n.attrs)
        if n.op in ("input", "param", "const"):
            attrs["dtype"] = dtype_map(str(n.dtype))
        if n.op == "cast":
            attrs["dtype"] = dtype_map(str(attrs["dtype"]))
        g2.add(n.op, n.inputs, name=n.name, **attrs)
    g2.set_outputs(graph.outputs)
    return g2


# ----------------------------------------------------------------------
# Shape/dtype inference: run the op's jnp implementation abstractly.
# ----------------------------------------------------------------------

def _infer(graph: Graph, op: str, inputs: List[str], attrs: Dict[str, Any]):
    if op in ("input", "param"):
        return tuple(attrs["shape"]), str(attrs["dtype"])
    if op == "const":
        return (), str(attrs.get("dtype", "float32"))
    from repro.ir.interpreter import op_impl  # local import to avoid a cycle
    fn = op_impl(op, attrs)
    in_structs = [jax.ShapeDtypeStruct(graph.nodes[i].shape,
                                       jnp.dtype(graph.nodes[i].dtype))
                  for i in inputs]
    out = jax.eval_shape(fn, *in_structs)
    return tuple(out.shape), str(out.dtype)
