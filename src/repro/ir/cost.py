"""Analytic TPU roofline cost model.

This is the pipeline's level-4 performance signal (the paper benchmarks on a
real Arc Pro B70; this container has no TPU, so we use a deterministic
speed-of-light model over v5e constants — the SOL-ExecBench-style metric the
paper recommends in §VII). The model is intentionally *structural*: every term
is a function of decisions the optimizer actually makes (fusion grouping, tile
sizes, layouts, dtypes, pipeline depth), so hill-climbing the model optimizes
the same levers that matter on hardware.

Per fusion group g with config c:
  traffic(g)  = Σ external-input re-reads under the blocking + external writes
                (+ accumulator spill round-trips when K is split non-persistently)
  t_mem       = traffic / (HBM_bw × mem_eff(layouts, alignment))
  t_comp      = Σ flops / (peak(unit, dtype) × util(c, dims))
  t(g)        = max(t_comp, t_mem) + launch_overhead        (pipelined)
              = t_comp + t_mem + launch_overhead            (num_stages == 1)
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.hw.specs import TPUSpec, TPU_V5E, dtype_itemsize
from repro.ir.graph import Graph, Node
from repro.ir.schedule import FusionGroup, KernelProgram, PallasConfig, Schedule

# planning figure for vector (non-MXU) compute on v5e
def _vpu_flops(spec: TPUSpec) -> float:
    return spec.peak_flops_f32 / 8.0


# op weight: how many VPU ops per element (transcendentals are expensive)
_EW_COST = {
    "exp": 4, "gelu": 8, "silu": 5, "swish": 5, "sigmoid": 5, "tanh": 5,
    "mish": 10, "softplus": 5, "softmax": 6, "logsumexp": 6,
    "layernorm": 8, "rmsnorm": 6, "instancenorm": 8, "batchnorm": 4,
    "groupnorm": 8, "pow": 4,
}


def _numel(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def node_flops_bytes(graph: Graph, node: Node,
                     dtype: Optional[str] = None) -> Tuple[float, float, float, str]:
    """Return (flops, read_bytes, write_bytes, unit) for one node.

    ``unit`` is "mxu" for contractions, "vpu" otherwise. Bytes use the node /
    schedule dtype and assume ideal (count-once) traffic; group-level blocking
    corrections happen in :class:`CostModel`.
    """
    dt = dtype or node.dtype
    isz = dtype_itemsize(dt)
    in_shapes = [graph.node(i).shape for i in node.inputs]
    read = sum(_numel(s) for s in in_shapes) * isz
    write = _numel(node.shape) * isz

    if node.op in ("matmul", "bmm"):
        a, b = in_shapes
        ta = node.attrs.get("transpose_a", False)
        tb = node.attrs.get("transpose_b", False)
        k = a[-2] if ta else a[-1]
        out = node.shape
        flops = 2.0 * _numel(out) * k
        return flops, read, write, "mxu"

    if node.op in ("conv2d", "conv3d"):
        w = in_shapes[1]  # OIHW / OIDHW
        recf = _numel(w[1:])  # Cin * prod(kernel)
        flops = 2.0 * _numel(node.shape) * recf
        return flops, read, write, "mxu"

    if node.op in ("conv_transpose2d", "conv_transpose3d"):
        w = in_shapes[1]  # IOHW: (Cin, Cout, k...)
        flops = 2.0 * _numel(in_shapes[0]) * (_numel(w) / max(w[0], 1))
        return flops, read, write, "mxu"

    if node.op in ("input", "param", "const"):
        return 0.0, 0.0, 0.0, "vpu"

    weight = _EW_COST.get(node.op, 1)
    base = max(_numel(node.shape), max((_numel(s) for s in in_shapes), default=0))
    return float(weight * base), read, write, "vpu"


def graph_flops(graph: Graph, dtype: Optional[str] = None) -> float:
    return sum(node_flops_bytes(graph, n, dtype)[0] for n in graph.toposorted())


@dataclasses.dataclass
class GroupCost:
    name: str
    t_compute: float
    t_memory: float
    t_total: float
    flops: float
    hbm_bytes: float
    bound: str  # "compute" | "memory" | "overhead"
    notes: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ProgramCost:
    total_s: float
    groups: List[GroupCost]
    flops: float                   # flops actually executed
    original_flops: float          # paper's "original accounting"
    hbm_bytes: float

    @property
    def tflops_effective(self) -> float:
        return self.original_flops / self.total_s / 1e12 if self.total_s else 0.0

    @property
    def dominant(self) -> str:
        if not self.groups:
            return "none"
        g = max(self.groups, key=lambda g: g.t_total)
        return f"{g.name}:{g.bound}"


class CostModel:
    def __init__(self, spec: TPUSpec = TPU_V5E):
        self.spec = spec

    # -- efficiency sub-models -----------------------------------------
    def _mxu_util(self, cfg: Optional[PallasConfig], m: int, n: int, k: int,
                  dtype: str, impl: str) -> float:
        if impl == "xla":
            base = 0.72  # XLA's stock emitters: good, not hand-tuned
            cfg = None
        elif impl == "pallas_naive":
            base = 0.55  # un-pipelined manual indexing stalls the MXU
        else:
            base = 0.88
        # problem-intrinsic alignment: the MXU is 128x128; tiny dims waste lanes
        eff_m = min(1.0, m / 128.0) if m < 128 else 1.0
        eff_n = min(1.0, n / 128.0) if n < 128 else 1.0
        align = 1.0
        if cfg is not None:
            for b, native in ((cfg.block_m, 128), (cfg.block_n, 128), (cfg.block_k, 128)):
                if b < native:
                    align *= max(0.25, b / native)
                elif b % native:
                    align *= 0.7
            if cfg.num_stages < 2 and impl == "pallas_blockspec":
                align *= 0.8
        if dtype in ("float32", "f32"):
            pass  # rate handled via peak_flops(dtype)
        return max(0.05, base * align * min(eff_m, 1.0) * min(eff_n, 1.0))

    def _mem_eff(self, group: FusionGroup, graph: Graph) -> float:
        eff = 0.85
        for operand, layout in group.operand_layouts.items():
            if layout in ("strided", "transposed"):
                eff = min(eff, 0.35)  # non-lane-contiguous HBM reads
            elif layout == "unmasked_ragged":
                eff = min(eff, 0.6)
        root = graph.node(group.root)
        if (root.op == "matmul" and root.attrs.get("transpose_b")
                and group.operand_layouts.get("b") != "packed"):
            # B stored [N, K]: K-major reads are column-strided until repacked
            eff = min(eff, 0.35)
        if group.impl == "pallas_naive":
            eff = min(eff, 0.5)      # no double-buffered copies
        if group.prefetch:
            eff = min(0.92, eff + 0.07)
        return eff

    # -- group-level traffic under blocking ------------------------------
    def _contraction_traffic(self, graph: Graph, group: FusionGroup, node: Node,
                             dtype: str) -> Tuple[float, List[str]]:
        notes = []
        isz = dtype_itemsize(dtype)
        a_shape = graph.node(node.inputs[0]).shape
        b_shape = graph.node(node.inputs[1]).shape
        out = node.shape
        if node.op in ("matmul", "bmm"):
            m, n = out[-2], out[-1]
            ta = node.attrs.get("transpose_a", False)
            k = a_shape[-2] if ta else a_shape[-1]
            batch = _numel(out[:-2])
        else:  # conv: treat as implicit GEMM
            m = _numel(out) // out[1] if len(out) > 1 else _numel(out)
            n = out[1]
            k = _numel(b_shape[1:])
            batch = 1
        cfg = group.config or PallasConfig()
        if group.impl == "xla":
            # XLA blocks well; assume near-ideal traffic
            traffic = (_numel(a_shape) + _numel(b_shape) + _numel(out)) * isz
            return traffic, notes
        bm, bn, bk = cfg.block_m, cfg.block_n, cfg.block_k
        mt = max(1, math.ceil(m / bm))
        nt = max(1, math.ceil(n / bn))
        kt = max(1, math.ceil(k / bk))
        # A re-read per n-tile unless the swizzle keeps it resident
        a_rereads = max(1, nt // max(1, cfg.group_m))
        b_rereads = mt  # B streams per m-tile (swizzle targets A-locality)
        a_traffic = _numel(a_shape) * isz * a_rereads
        b_traffic = _numel(b_shape) * isz * b_rereads
        c_traffic = _numel(out) * isz
        if kt > 1 and not cfg.persistent:
            # non-persistent K-split spills partials to HBM every k-step
            c_traffic += _numel(out) * 4 * 2 * (kt - 1)
            notes.append(f"k-split x{kt} spills partials (persistent=False)")
        if a_rereads > 1:
            notes.append(f"A re-read x{a_rereads} (group_m={cfg.group_m})")
        return a_traffic + b_traffic + c_traffic, notes

    # -- main entry -------------------------------------------------------
    def group_cost(self, graph: Graph, sched: Schedule, group: FusionGroup) -> GroupCost:
        spec = self.spec
        dtype = sched.compute_dtype
        # nodes carrying a wider dtype than the schedule (e.g. float64 graphs
        # before the dtype stage) dominate: storage and compute pay for it.
        # Source dtypes are checked too — with x64 disabled, JAX canonicalizes
        # inferred dtypes to f32, so the declared f64 only survives on sources.
        names = set(group.nodes)
        for n in group.nodes:
            node = graph.node(n)
            if str(node.dtype) == "float64" or any(
                    str(graph.node(i).dtype) == "float64" for i in node.inputs):
                dtype = "float64"
                break
        isz = dtype_itemsize(dtype)
        nodes = [graph.node(n) for n in group.nodes]
        produced = set(group.nodes)
        notes: List[str] = []

        flops_mxu = 0.0
        flops_vpu = 0.0
        contraction: Optional[Node] = None
        for n in nodes:
            f, _, _, unit = node_flops_bytes(graph, n, dtype)
            if unit == "mxu":
                flops_mxu += f
                contraction = n if contraction is None else contraction
            else:
                flops_vpu += f

        # external traffic: inputs read once per blocking model, outputs written once
        ext_read = 0.0
        for n in nodes:
            for i in n.inputs:
                if i not in produced:
                    src = graph.node(i)
                    if contraction is not None and i in contraction.inputs:
                        continue  # accounted by the blocking model below
                    ext_read += _numel(src.shape) * dtype_itemsize(
                        dtype if src.op != "const" else src.dtype)
        ext_write = 0.0
        consumers_outside = 0
        for n in nodes:
            is_out = n.name in graph.outputs
            ext_consumers = [c for c in graph.consumers(n.name) if c.name not in produced]
            if is_out or ext_consumers:
                ext_write += _numel(n.shape) * isz
                consumers_outside += 1

        traffic = ext_read + ext_write
        if contraction is not None:
            ct, cn = self._contraction_traffic(graph, group, contraction, dtype)
            traffic += ct
            notes += cn
            # XLA fuses elementwise epilogues into GEMM/conv, but cannot keep
            # the product unmaterialized across a *reduction* epilogue — only a
            # hand kernel (pallas) earns that traffic elision.
            if group.impl == "xla" and any(
                    graph.node(n).is_reduction() for n in group.nodes
                    if n != contraction.name):
                traffic += 2 * _numel(contraction.shape) * dtype_itemsize(dtype)
                notes.append("xla: reduction epilogue re-materializes the product")

        mem_eff = self._mem_eff(group, graph)
        t_mem = traffic / (spec.hbm_bw * mem_eff)

        t_comp = 0.0
        if flops_mxu:
            if contraction is not None and contraction.op in ("matmul", "bmm"):
                m, n_ = contraction.shape[-2], contraction.shape[-1]
                a_shape = graph.node(contraction.inputs[0]).shape
                k = a_shape[-2] if contraction.attrs.get("transpose_a") else a_shape[-1]
            else:
                m = n_ = k = 512
            util = self._mxu_util(group.config, m, n_, k, dtype, group.impl)
            t_comp += flops_mxu / (spec.peak_flops(dtype) * util)
        if flops_vpu:
            t_comp += flops_vpu / _vpu_flops(spec)

        cfg = group.config
        pipelined = group.impl != "pallas_naive" and (cfg is None or cfg.num_stages >= 2)
        if pipelined:
            t = max(t_comp, t_mem)
        else:
            t = t_comp + t_mem
            notes.append("no copy/compute overlap (naive or stages=1)")
        t += spec.launch_overhead_s
        bound = ("compute" if t_comp >= t_mem else "memory")
        if spec.launch_overhead_s > 0.5 * t:
            bound = "overhead"
        return GroupCost(group.name, t_comp, t_mem, t, flops_mxu + flops_vpu,
                         traffic, bound, notes)

    def program_cost(self, program: KernelProgram) -> ProgramCost:
        groups = [self.group_cost(program.graph, program.schedule, g)
                  for g in program.schedule.groups]
        total = sum(g.t_total for g in groups)
        if program.meta.get("host_sync") and not program.meta.get("host_sync_removed"):
            total += 50e-6  # host round-trip stall between launches
        return ProgramCost(
            total_s=total,
            groups=groups,
            flops=sum(g.flops for g in groups),
            original_flops=program.original_flops or sum(g.flops for g in groups),
            hbm_bytes=sum(g.hbm_bytes for g in groups),
        )

    def program_time(self, program: KernelProgram) -> float:
        return self.program_cost(program).total_s

    def program_rank_estimate(self, program: KernelProgram) -> Tuple[float, float]:
        """(total_s, hbm_bytes) — the pair proposal ordering ranks candidates
        by. The secondary HBM-traffic coordinate breaks ties between
        candidates the roofline prices identically (e.g. two fusions with the
        same dominant group) in favor of the one moving fewer bytes."""
        cost = self.program_cost(program)
        return (cost.total_s, cost.hbm_bytes)
