"""AdamW with cosine schedule, linear warmup and global-norm clipping —
pure JAX, pytree-native, sharding-transparent (moments inherit the param
sharding through jit output shardings)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: AdamWConfig, params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def update(cfg: AdamWConfig, grads, state: OptState, params
           ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias excluded)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(cfg.moment_dtype), v2.astype(cfg.moment_dtype)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(new_m, new_v, step), metrics
