"""Device-aware timing (paper §V-A-d, adapted to this container).

``time_fn`` reproduces the AI Bench methodology at CPU scale: warmup
iterations to stabilize caches/JIT, a measurement loop with block_until_ready
(the synchronization-barrier analogue), trimming of the extreme min/max, and
mean over the rest. There is no GPU command stream to fill on CPU, so the
dummy-matmul trick is replaced by an explicit pre-dispatch. Cache flushing is
approximated by touching a flush buffer between iterations (best-effort on
CPU; exact on the paper's hardware).
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import numpy as np

_FLUSH = None


def _flush_cache(mb: int = 64):
    global _FLUSH
    if _FLUSH is None or _FLUSH.nbytes < mb << 20:
        _FLUSH = np.zeros((mb << 20) // 8, np.float64)
    _FLUSH[:] = 0.0


def time_fn(fn: Callable, args: Sequence = (), *, warmup: int = 5,
            iters: int = 20, flush: bool = False, trim: int = 1) -> dict:
    """Return {mean_us, min_us, max_us, std_us, iters} for fn(*args)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        if flush:
            _flush_cache()
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    kept = samples[trim:-trim] if len(samples) > 2 * trim else samples
    return {
        "mean_us": float(np.mean(kept)),
        "min_us": float(samples[0]),
        "max_us": float(samples[-1]),
        "std_us": float(np.std(kept)),
        "iters": len(samples),
    }


def derive_metrics(mean_us: float, flops: float = None, bytes_: float = None) -> dict:
    out = {}
    if flops:
        out["tflops"] = flops / (mean_us * 1e6)
    if bytes_:
        out["gbps"] = bytes_ / (mean_us * 1e3)
    return out
