"""The Level-2 kernel suite (KernelBench-L2 analogue, paper §VI-B/C).

Each builder constructs the problem graph at given dims and returns a
:class:`KernelProgram` in one of four schedules:

  * ``eager``    — singleton XLA groups          (PyTorch-eager analogue)
  * ``compiled`` — greedy-fused XLA groups       (torch.compile analogue)
  * ``naive``    — KernelFalcon-analogue input: contractions as naive Pallas
                   kernels with imported NVIDIA-default configs (128,128,32),
                   everything else eager — the pipeline's starting point
  * (the pipeline's output is the fourth column)

Builders are registered by name; the YAML specs bind dims/tolerances.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.ir.cost import graph_flops
from repro.ir.graph import Graph, GraphBuilder
from repro.ir.schedule import (KernelProgram, PallasConfig, Schedule,
                               eager_schedule, greedy_fused_schedule)

BUILDERS: Dict[str, Callable[..., Graph]] = {}


def register(name):
    def deco(fn):
        BUILDERS[name] = fn
        return fn
    return deco


NVIDIA_DEFAULT = dict(block_m=128, block_n=128, block_k=32, num_stages=1)


def naive_schedule(g: Graph) -> Schedule:
    s = eager_schedule(g)
    for grp in s.groups:
        root = g.node(grp.root)
        if root.op == "matmul" and len(root.shape) == 2:
            grp.impl = "pallas_naive"
            grp.config = PallasConfig(**NVIDIA_DEFAULT)
    return s


def build_program(name: str, dims: Dict[str, int], schedule: str = "naive",
                  meta: Dict = None) -> KernelProgram:
    g = BUILDERS[name](**dims)
    sched = {"eager": eager_schedule, "compiled": greedy_fused_schedule,
             "naive": naive_schedule}[schedule](g)
    p = KernelProgram(name, g, sched, original_flops=graph_flops(g),
                      meta=dict(meta or {}))
    p.validate()
    return p


# ======================================================================
# GEMM family
# ======================================================================

@register("gemm_bias_gelu")
def _(M, N, K):
    b = GraphBuilder("gemm_bias_gelu")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    bias = b.param((N,), name="bias")
    mm = b.matmul(x, w, name="mm")
    y = b.bias_add(mm, bias, name="biased")
    return b.done(b.gelu(y, name="act"))


@register("gemm_swish_tanh_scale")
def _(M, N, K):
    b = GraphBuilder("gemm_swish_tanh_scale")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.scale(b.tanh(b.silu(mm, name="sw"), name="th"),
                          value=2.0, name="sc"))


@register("gemm_max_subtract_gelu")
def _(M, N, K):
    b = GraphBuilder("gemm_max_subtract_gelu")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    mx = b.reduce_max(mm, axes=(1,), name="rowmax")
    return b.done(b.gelu(b.add_scalar(mx, value=-0.5, name="sub"), name="act"))


@register("gemm_divide_sum")
def _(M, N, K):
    b = GraphBuilder("gemm_divide_sum")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.reduce_sum(b.scale(mm, value=0.5, name="half"),
                               axes=(1,), name="rowsum"))


@register("gemm_scale_residual")
def _(M, N, K):
    b = GraphBuilder("gemm_scale_residual")
    x = b.input((M, K), name="x")
    r = b.input((M, N), name="resid")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.add(b.scale(mm, value=0.125, name="sc"), r, name="res"))


@register("gemm_branch_duplicate")
def _(M, N, K):
    b = GraphBuilder("gemm_branch_duplicate")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    m1 = b.matmul(x, w, name="mm1")
    g1 = b.gelu(m1, name="g1")
    m2 = b.matmul(x, w, name="mm2")
    g2 = b.gelu(m2, name="g2")
    return b.done(b.add(g1, g2, name="sum"))


@register("gemm_f64_sigmoid")
def _(M, N, K):
    b = GraphBuilder("gemm_f64_sigmoid", dtype="float64")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.sigmoid(mm, name="sig"))


@register("gemm_mean_scale")
def _(M, N, K):
    b = GraphBuilder("gemm_mean_scale")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    mean = b.reduce_mean(mm, axes=(1,), name="rowmean")
    return b.done(b.scale(mean, value=3.0, name="sc"))


@register("gemm_softplus_min")
def _(M, N, K):
    b = GraphBuilder("gemm_softplus_min")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.reduce_min(b.softplus(mm, name="sp"), axes=(1,), name="rowmin"))


@register("gemm_transpose_transpose")
def _(M, N, K):
    b = GraphBuilder("gemm_transpose_transpose")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    t1 = b.transpose(x, perm=(1, 0), name="t1")
    t2 = b.transpose(t1, perm=(1, 0), name="t2")
    mm = b.matmul(t2, w, name="mm")
    return b.done(b.relu(mm, name="act"))


# ======================================================================
# MatMul family (layout / cleanup)
# ======================================================================

@register("matmul_t_gelu")
def _(M, N, K):
    b = GraphBuilder("matmul_t_gelu")
    x = b.input((M, K), name="x")
    w = b.param((N, K), name="w")       # torch Linear layout
    mm = b.matmul(x, w, transpose_b=True, name="mm")
    return b.done(b.gelu(mm, name="act"))


@register("matmul_min_subtract")
def _(M, N, K):
    b = GraphBuilder("matmul_min_subtract")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    mn = b.reduce_min(mm, axes=(1,), name="rowmin")
    return b.done(b.add_scalar(mn, value=-1.0, name="sub"))


@register("matmul_t_scale_swish")
def _(M, N, K):
    b = GraphBuilder("matmul_t_scale_swish")
    x = b.input((M, K), name="x")
    w = b.param((N, K), name="w")
    mm = b.matmul(x, w, transpose_b=True, name="mm")
    return b.done(b.silu(b.scale(mm, value=0.25, name="sc"), name="sw"))


@register("matmul_serial_sum")
def _(M, N, K):
    b = GraphBuilder("matmul_serial_sum")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    s = b.g.add("reduce_sum", (mm,), name="rowsum", axes=(1,),
                accumulate="serial")
    return b.done(s)


@register("matmul_materialized_t")
def _(M, N, K):
    b = GraphBuilder("matmul_materialized_t")
    x = b.input((K, M), name="x")
    w = b.param((K, N), name="w")
    xt = b.transpose(x, perm=(1, 0), name="xt")
    mm = b.matmul(xt, w, name="mm")
    return b.done(b.tanh(mm, name="act"))


@register("matmul_dropout_tanh")
def _(M, N, K):
    b = GraphBuilder("matmul_dropout_tanh")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    dp = b.dropout(mm, name="drop")
    return b.done(b.tanh(dp, name="act"))


@register("matmul_double_cast")
def _(M, N, K):
    b = GraphBuilder("matmul_double_cast")
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    c1 = b.cast(mm, dtype="float32", name="c1")
    c2 = b.cast(c1, dtype="float32", name="c2")
    return b.done(b.gelu(c2, name="act"))


# ======================================================================
# BMM
# ======================================================================

@register("bmm_instnorm_sum_residual")
def _(B, M, N, K):
    b = GraphBuilder("bmm_instnorm_sum_residual")
    x = b.input((B, M, K), name="x")
    y = b.input((B, K, N), name="y")
    r = b.input((B, M), name="resid")
    mm = b.bmm(x, y, name="mm")
    nrm = b.instancenorm(mm, name="inorm")
    s = b.reduce_sum(nrm, axes=(2,), name="sum")
    return b.done(b.mul(b.add(s, r, name="res"), r, name="mul"))


# ======================================================================
# Conv families (NCHW graphs; optimizer may run channels-last internally)
# ======================================================================

@register("conv2d_bn_relu")
def _(B, Cin, Cout, H, W, KS):
    b = GraphBuilder("conv2d_bn_relu")
    x = b.input((B, Cin, H, W), name="x")
    w = b.param((Cout, Cin, KS, KS), name="w")
    scale = b.param((Cout,), name="bn_scale", init="uniform01")
    bias = b.param((Cout,), name="bn_bias")
    mean = b.param((Cout,), name="bn_mean")
    var = b.param((Cout,), name="bn_var", init="uniform01")
    cv = b.conv2d(x, w, name="conv")
    bn = b.batchnorm(cv, scale, bias, mean, var, name="bn")
    return b.done(b.relu(bn, name="act"))


@register("conv2d_gelu_scale")
def _(B, Cin, Cout, H, W, KS):
    b = GraphBuilder("conv2d_gelu_scale")
    x = b.input((B, Cin, H, W), name="x")
    w = b.param((Cout, Cin, KS, KS), name="w")
    cv = b.conv2d(x, w, name="conv")
    return b.done(b.scale(b.gelu(cv, name="act"), value=1.5, name="sc"))


@register("conv2d_f64_tanh")
def _(B, Cin, Cout, H, W, KS):
    b = GraphBuilder("conv2d_f64_tanh", dtype="float64")
    x = b.input((B, Cin, H, W), name="x")
    w = b.param((Cout, Cin, KS, KS), name="w")
    cv = b.conv2d(x, w, name="conv")
    return b.done(b.tanh(cv, name="act"))


@register("conv2d_min_clamp")
def _(B, Cin, Cout, H, W, KS):
    b = GraphBuilder("conv2d_min_clamp")
    x = b.input((B, Cin, H, W), name="x")
    w = b.param((Cout, Cin, KS, KS), name="w")
    cv = b.conv2d(x, w, name="conv")
    return b.done(b.clamp_max(b.clamp_min(cv, value=-1.0, name="lo"),
                              value=1.0, name="hi"))


@register("conv3d_relu_scale")
def _(B, Cin, Cout, D, H, W, KS):
    b = GraphBuilder("conv3d_relu_scale")
    x = b.input((B, Cin, D, H, W), name="x")
    w = b.param((Cout, Cin, KS, KS, KS), name="w")
    cv = b.conv3d(x, w, name="conv")
    return b.done(b.scale(b.relu(cv, name="act"), value=0.5, name="sc"))


@register("conv3d_groupnorm_mish")
def _(B, Cin, Cout, D, H, W, KS):
    b = GraphBuilder("conv3d_groupnorm_mish")
    x = b.input((B, Cin, D, H, W), name="x")
    w = b.param((Cout, Cin, KS, KS, KS), name="w")
    cv = b.conv3d(x, w, name="conv")
    gn = b.groupnorm(cv, groups=8, name="gn")
    return b.done(b.mish(gn, name="act"))


@register("convt2d_multiply_gap")
def _(B, Cin, Cout, H, W, KS):
    b = GraphBuilder("convt2d_multiply_gap")
    x = b.input((B, Cin, H, W), name="x")
    w = b.param((Cin, Cout, KS, KS), name="w")
    cv = b.conv_transpose2d(x, w, stride=2, name="convt")
    sc = b.scale(cv, value=0.7, name="mul")
    return b.done(b.globalavgpool(sc, name="gap"))


@register("convt2d_tanh")
def _(B, Cin, Cout, H, W, KS):
    b = GraphBuilder("convt2d_tanh")
    x = b.input((B, Cin, H, W), name="x")
    w = b.param((Cin, Cout, KS, KS), name="w")
    cv = b.conv_transpose2d(x, w, stride=2, name="convt")
    return b.done(b.tanh(cv, name="act"))


@register("convt3d_silu")
def _(B, Cin, Cout, D, H, W, KS):
    b = GraphBuilder("convt3d_silu")
    x = b.input((B, Cin, D, H, W), name="x")
    w = b.param((Cin, Cout, KS, KS, KS), name="w")
    cv = b.conv_transpose3d(x, w, stride=2, name="convt")
    return b.done(b.silu(cv, name="act"))


@register("convt3d_add_relu")
def _(B, Cin, Cout, D, H, W, KS):
    b = GraphBuilder("convt3d_add_relu")
    x = b.input((B, Cin, D, H, W), name="x")
    w = b.param((Cin, Cout, KS, KS, KS), name="w")
    cv = b.conv_transpose3d(x, w, stride=1, name="convt")
    r = b.input((B, Cout, D, H, W), name="resid")
    return b.done(b.relu(b.add(cv, r, name="res"), name="act"))
