"""Standalone benchmark_compare (paper §V-A-f): validates an optimized
program against the reference with seeded weights, cloned inputs and
structured mismatch diagnostics. The pipeline's verifier embeds the same
logic; this module is the user-facing entry point AI Bench exposes."""

from __future__ import annotations

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import run_program
from repro.ir.interpreter import evaluate, make_inputs, make_params
from repro.ir.schedule import KernelProgram


def set_all_seeds(seed: int = 0):
    """Seed every RNG domain (numpy, python; jax keys are explicit)."""
    np.random.seed(seed)
    random.seed(seed)
    return jax.random.PRNGKey(seed)


@dataclasses.dataclass
class ComparisonResult:
    correct: bool
    max_abs_diff: float
    mean_diff: float
    max_rel_diff: float
    exceed_count: int
    exceed_pct: float
    nan_in_output: bool
    inf_introduced: bool
    feedback: str


def compare_programs(reference: KernelProgram, optimized: KernelProgram,
                     rtol: float = 1e-2, atol: float = 1e-5,
                     seed: int = 0, use_pallas: bool = True) -> ComparisonResult:
    set_all_seeds(seed)
    inputs = make_inputs(reference.graph, seed=seed + 1)
    params = make_params(reference.graph, seed=seed)
    # weight copy: state_dict-style by name; shape-matched positional fallback
    opt_params = {}
    opt_names = [p.name for p in optimized.graph.params()]
    for name in opt_names:
        if name in params:
            opt_params[name] = params[name]
    if len(opt_params) != len(opt_names):
        ref_left = [v for k, v in params.items() if k not in opt_params]
        for name in opt_names:
            if name in opt_params:
                continue
            shape = optimized.graph.node(name).shape
            for i, v in enumerate(ref_left):
                if v.shape == shape:
                    opt_params[name] = ref_left.pop(i)
                    break
    # cloned inputs guard against in-place mutation
    ref_out = evaluate(reference.graph, {k: jnp.array(v) for k, v in inputs.items()},
                       params)
    opt_out = run_program(optimized, {k: jnp.array(v) for k, v in inputs.items()},
                          opt_params, use_pallas=use_pallas)

    worst = None
    nan_found = False
    inf_introduced = False
    for (rk, rv), (ok_, ov) in zip(ref_out.items(), opt_out.items()):
        rv = np.asarray(rv, np.float64)
        ov = np.asarray(ov, np.float64)
        nan_found |= bool(np.isnan(ov).any())
        inf_introduced |= bool(np.isinf(ov).any() and not np.isinf(rv).any())
        adiff = np.abs(ov - rv)
        rdiff = adiff / np.maximum(np.abs(rv), 1e-12)
        exceed = adiff > (atol + rtol * np.abs(rv))
        stats = (float(adiff.max()), float(adiff.mean()), float(rdiff.max()),
                 int(exceed.sum()), 100.0 * float(exceed.mean()))
        if worst is None or stats[0] > worst[0]:
            worst = stats
    correct = (not nan_found and not inf_introduced
               and worst is not None and worst[3] == 0)
    feedback = ("PASS" if correct else
                f"max_abs={worst[0]:.3e} mean={worst[1]:.3e} "
                f"max_rel={worst[2]:.3e} exceed={worst[3]} ({worst[4]:.2f}%)"
                + (" NaN!" if nan_found else "")
                + (" Inf introduced!" if inf_introduced else ""))
    return ComparisonResult(correct, worst[0], worst[1], worst[2], worst[3],
                            worst[4], nan_found, inf_introduced, feedback)
