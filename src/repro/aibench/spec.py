"""AI Bench problem specifications (paper §V-A-b).

Problems are declared in YAML with symbolic dimensions, per-variant bindings
(``ci`` for fast validation, ``bench`` for deployment shapes), FLOP / byte
formulas evaluated by a safe AST evaluator (only + - * / ** and names), dtypes
and tolerances. The graph *builder* is referenced by name and resolved from
the suite registry — specs describe the contract, builders the computation.
"""

from __future__ import annotations

import ast
import dataclasses
import operator
import pathlib
from typing import Any, Dict, List, Optional

import yaml

_BINOPS = {
    ast.Add: operator.add, ast.Sub: operator.sub, ast.Mult: operator.mul,
    ast.Div: operator.truediv, ast.Pow: operator.pow,
    ast.FloorDiv: operator.floordiv,
}


def safe_eval(expr: str, env: Dict[str, float]) -> float:
    """Evaluate an arithmetic formula over dimension variables.
    Only numbers, names, + - * / ** // and unary minus are allowed."""

    def ev(node):
        if isinstance(node, ast.Expression):
            return ev(node.body)
        if isinstance(node, ast.Constant):
            if not isinstance(node.value, (int, float)):
                raise ValueError(f"non-numeric constant {node.value!r}")
            return node.value
        if isinstance(node, ast.Name):
            if node.id not in env:
                raise KeyError(f"unknown dimension {node.id!r}")
            return env[node.id]
        if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
            return _BINOPS[type(node.op)](ev(node.left), ev(node.right))
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -ev(node.operand)
        raise ValueError(f"disallowed syntax: {ast.dump(node)}")

    return ev(ast.parse(expr, mode="eval"))


@dataclasses.dataclass
class Variant:
    name: str                      # ci | bench
    dims: Dict[str, int]
    dtype: str = "float32"


@dataclasses.dataclass
class ProblemSpec:
    name: str
    family: str                    # gemm | matmul | bmm | conv2d | ...
    builder: str                   # suite registry key
    tags: List[str]
    variants: Dict[str, Variant]
    flops_formula: Optional[str] = None
    bytes_formula: Optional[str] = None
    rtol: float = 1e-2
    atol: float = 1e-5
    target_dtype: str = "bfloat16"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def dims(self, variant: str) -> Dict[str, int]:
        return dict(self.variants[variant].dims)

    def flops(self, variant: str) -> Optional[float]:
        if not self.flops_formula:
            return None
        return safe_eval(self.flops_formula, self.dims(variant))

    def bytes(self, variant: str) -> Optional[float]:
        if not self.bytes_formula:
            return None
        return safe_eval(self.bytes_formula, self.dims(variant))


def load_specs(path: Optional[pathlib.Path] = None) -> List[ProblemSpec]:
    path = pathlib.Path(path or pathlib.Path(__file__).parent / "specs")
    specs: List[ProblemSpec] = []
    for f in sorted(path.glob("*.yaml")):
        doc = yaml.safe_load(f.read_text()) or {}
        for p in doc.get("problems", []) or []:
            variants = {}
            for vname, v in (p.get("variants") or {}).items():
                variants[vname] = Variant(name=vname, dims=dict(v.get("dims", {})),
                                          dtype=v.get("dtype", "float32"))
            specs.append(ProblemSpec(
                name=p["name"], family=p.get("family", "gemm"),
                builder=p.get("builder", p["name"]),
                tags=list(p.get("tags", []) or []),
                variants=variants,
                flops_formula=p.get("flops"),
                bytes_formula=p.get("bytes"),
                rtol=float(p.get("rtol", 1e-2)),
                atol=float(p.get("atol", 1e-5)),
                target_dtype=p.get("target_dtype", "bfloat16"),
                meta=dict(p.get("meta", {}) or {})))
    return specs
