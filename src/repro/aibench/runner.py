"""Kernel runners (paper §V-A integration): KernelRunner executes one
problem spec end to end — builds the three baselines and the pipeline's
optimized program, derives modeled TPU timings + TFLOPS for every backend,
validates correctness, measures CPU wall-clock at ci shapes as a secondary
signal, and logs CSV rows. SuiteRunner batches the full suite through the
fleet :class:`OptimizationEngine` (bounded worker pool + fingerprint-keyed
result cache) and aggregates the paper's headline metrics (geomean speedup,
%improved, >5x set) plus the engine's cache statistics."""

from __future__ import annotations

import dataclasses
import math
import pathlib
from typing import List, Optional

from repro.aibench.compare import compare_programs
from repro.aibench.csvlog import CSVLogger
from repro.aibench.spec import ProblemSpec, load_specs
from repro.aibench.suite import build_program
from repro.aibench.timing import time_fn
from repro.core.config import ForgeConfig
from repro.core.engine import (EngineResult, EngineStats, KernelJob,
                               VerifyStats)
from repro.core.forge import Forge
from repro.core.pipeline import PipelineResult
from repro.ir.cost import CostModel
from repro.ir.interpreter import make_inputs, make_params
from repro.core.executor import run_program


@dataclasses.dataclass
class KernelResult:
    name: str
    family: str
    eager_us: float
    compiled_us: float
    naive_us: float
    optimized_us: float
    correct: bool
    stage_log: List
    tflops_optimized: float
    cache_hit: bool = False
    transfer: bool = False          # warm-started from a family neighbor
    seed_steps: int = 0

    @property
    def speedup_vs_eager(self) -> float:
        return self.eager_us / self.optimized_us

    @property
    def speedup_vs_best_baseline(self) -> float:
        return min(self.eager_us, self.compiled_us) / self.optimized_us

    @property
    def speedup_vs_naive(self) -> float:
        return self.naive_us / self.optimized_us


class KernelRunner:
    """Single-spec runner; suite-level batching lives in SuiteRunner. The
    runner is split into ``make_job`` (build the programs) and ``finish``
    (baseline timings + correctness + logging) so the engine can own the
    optimization step in between. All engine wiring goes through the
    :class:`Forge` facade — pass a ``config`` to set knobs, or share a
    pre-built ``forge``."""

    def __init__(self, config: Optional[ForgeConfig] = None,
                 logger: Optional[CSVLogger] = None,
                 measure_wallclock: bool = False,
                 forge: Optional[Forge] = None,
                 backend: Optional[str] = None):
        if forge is not None and config is not None \
                and forge.config is not config:
            raise ValueError("pass either config or forge, not two "
                             "disagreeing ones — the forge's config runs")
        if backend is not None:
            if forge is not None:
                raise ValueError("backend= is a config shorthand; a "
                                 "pre-built forge already fixed its backend")
            config = (config or ForgeConfig()).replace(
                execution_backend=backend)
        self.forge = forge or Forge(config or ForgeConfig())
        self.engine = self.forge.engine
        self.pipeline = self.forge.pipeline
        self.cost = CostModel(self.pipeline.spec)
        self.logger = logger
        self.measure_wallclock = measure_wallclock

    # ------------------------------------------------------------------
    def make_job(self, spec: ProblemSpec) -> KernelJob:
        return KernelJob(
            name=spec.name,
            ci_program=build_program(spec.builder, spec.dims("ci"), "naive",
                                     meta=spec.meta),
            bench_program=build_program(spec.builder, spec.dims("bench"),
                                        "naive", meta=spec.meta),
            tags=tuple(spec.tags), target_dtype=spec.target_dtype,
            rtol=spec.rtol, atol=spec.atol, meta=dict(spec.meta))

    # ------------------------------------------------------------------
    def finish(self, spec: ProblemSpec, eres: EngineResult) -> KernelResult:
        res: PipelineResult = eres.result
        eager = build_program(spec.builder, spec.dims("bench"), "eager",
                              meta=spec.meta)
        compiled = build_program(spec.builder, spec.dims("bench"), "compiled",
                                 meta=spec.meta)
        # the job's bench program is untouched (the pipeline/replay operate
        # on copies), so it still is the pristine naive baseline
        naive_bench = eres.job.bench_program

        cmp_res = compare_programs(
            build_program(spec.builder, spec.dims("ci"), "eager", meta=spec.meta),
            res.ci_program, rtol=spec.rtol, atol=spec.atol)

        t_eager = self.cost.program_time(eager)
        t_compiled = self.cost.program_time(compiled)
        t_naive = self.cost.program_time(naive_bench)
        t_opt = self.cost.program_time(res.bench_program)
        opt_cost = self.cost.program_cost(res.bench_program)

        result = KernelResult(
            name=spec.name, family=spec.family,
            eager_us=t_eager * 1e6, compiled_us=t_compiled * 1e6,
            naive_us=t_naive * 1e6, optimized_us=t_opt * 1e6,
            correct=cmp_res.correct, stage_log=res.stage_records,
            tflops_optimized=opt_cost.tflops_effective,
            cache_hit=eres.cache_hit, transfer=eres.transfer,
            seed_steps=eres.seed_steps)

        if self.logger:
            flops = spec.flops("bench") or res.bench_program.original_flops
            for backend, us in (("pytorch", result.eager_us),
                                ("pytorch-compile", result.compiled_us),
                                ("triton-unoptimized", result.naive_us),
                                ("triton-optimized", result.optimized_us)):
                self.logger.log(kernel=spec.name, backend=backend,
                                flops=flops, tflops=flops / (us * 1e6),
                                time_us=us, dims=spec.dims("bench"),
                                note=f"correct={cmp_res.correct} "
                                     f"cache_hit={eres.cache_hit} "
                                     f"transfer={eres.transfer}")
        if self.measure_wallclock:
            ci_in = make_inputs(res.ci_program.graph, seed=1)
            ci_par = make_params(res.ci_program.graph, seed=0)
            wc = time_fn(lambda: run_program(res.ci_program, ci_in, ci_par,
                                             use_pallas=False),
                         warmup=2, iters=5)
            if self.logger:
                self.logger.log(kernel=spec.name, backend="ci-wallclock-cpu",
                                time_us=wc["mean_us"], dims=spec.dims("ci"))
        return result

    # ------------------------------------------------------------------
    def run(self, spec: ProblemSpec) -> KernelResult:
        return self.finish(spec, self.forge.optimize(self.make_job(spec)).result)

    def close(self):
        """Release the forge's executor resources (the process backend
        keeps spawned workers warm between batches)."""
        self.forge.close()

    def __enter__(self) -> "KernelRunner":
        return self

    def __exit__(self, *exc):
        self.close()


@dataclasses.dataclass
class SuiteSummary:
    results: List[KernelResult]
    engine_stats: Optional[EngineStats] = None
    # verify-layer counters (oracle/group memo hits, shared-cache hits,
    # planner dedup) — kept apart from engine_stats because shared-hit
    # counts vary by backend (see repro.core.engine.VerifyStats)
    verify_stats: Optional[VerifyStats] = None

    def _geomean(self, vals: List[float]) -> float:
        vals = [max(v, 1e-9) for v in vals]
        return math.exp(sum(math.log(v) for v in vals) / len(vals)) if vals else 0.0

    @property
    def geomean_vs_eager(self) -> float:
        return self._geomean([r.speedup_vs_eager for r in self.results])

    @property
    def geomean_vs_best(self) -> float:
        return self._geomean([r.speedup_vs_best_baseline for r in self.results])

    @property
    def pct_improved(self) -> float:
        n = sum(1 for r in self.results if r.speedup_vs_eager > 1.0)
        return 100.0 * n / len(self.results) if self.results else 0.0

    @property
    def over_5x(self) -> List[KernelResult]:
        return [r for r in self.results if r.speedup_vs_best_baseline > 5.0]

    @property
    def all_correct(self) -> bool:
        return all(r.correct for r in self.results)

    @property
    def cache_hits(self) -> int:
        return sum(1 for r in self.results if r.cache_hit)

    @property
    def transfers(self) -> int:
        return sum(1 for r in self.results if r.transfer)


class SuiteRunner:
    def __init__(self, config: Optional[ForgeConfig] = None,
                 csv_path: Optional[pathlib.Path] = None,
                 families: Optional[List[str]] = None,
                 forge: Optional[Forge] = None,
                 backend: Optional[str] = None):
        logger = CSVLogger(csv_path) if csv_path else None
        if forge is not None and config is not None \
                and forge.config is not config:
            raise ValueError("pass either config or forge, not two "
                             "disagreeing ones — the forge's config runs")
        if backend is not None:
            if forge is not None:
                raise ValueError("backend= is a config shorthand; a "
                                 "pre-built forge already fixed its backend")
            config = (config or ForgeConfig()).replace(
                execution_backend=backend)
        self.forge = forge or Forge(config or ForgeConfig())
        self.engine = self.forge.engine
        self.runner = KernelRunner(logger=logger, forge=self.forge)
        self.families = families

    def close(self):
        """Release the forge's executor resources (the process backend
        keeps spawned workers warm between batches)."""
        self.forge.close()

    def __enter__(self) -> "SuiteRunner":
        return self

    def __exit__(self, *exc):
        self.close()

    def run(self, specs: Optional[List[ProblemSpec]] = None,
            verbose: bool = True) -> SuiteSummary:
        specs = specs or load_specs()
        if self.families:
            specs = [s for s in specs if s.family in self.families]
        jobs = [self.runner.make_job(s) for s in specs]
        eresults = self.forge.optimize_batch(jobs).results
        results = []
        for spec, eres in zip(specs, eresults):
            r = self.runner.finish(spec, eres)
            results.append(r)
            if verbose:
                hit = (" cache" if r.cache_hit
                       else f" transfer({r.seed_steps})" if r.transfer else "")
                print(f"  {r.name:28s} [{r.family:7s}] eager={r.eager_us:9.1f}us "
                      f"compile={r.compiled_us:9.1f}us naive={r.naive_us:10.1f}us "
                      f"-> opt={r.optimized_us:9.1f}us  "
                      f"x{r.speedup_vs_eager:7.2f} vs eager  "
                      f"x{r.speedup_vs_best_baseline:6.2f} vs best  "
                      f"correct={r.correct}{hit}")
        return SuiteSummary(results, engine_stats=self.engine.stats,
                            verify_stats=self.engine.verify_stats)
