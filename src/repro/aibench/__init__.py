from repro.aibench.spec import ProblemSpec, Variant, load_specs, safe_eval
from repro.aibench.suite import BUILDERS, build_program, naive_schedule
from repro.aibench.runner import KernelRunner, SuiteRunner, SuiteSummary
from repro.aibench.compare import compare_programs, set_all_seeds
from repro.aibench.timing import time_fn
from repro.aibench.csvlog import CSVLogger

__all__ = [
    "ProblemSpec", "Variant", "load_specs", "safe_eval", "BUILDERS",
    "build_program", "naive_schedule", "KernelRunner", "SuiteRunner",
    "SuiteSummary", "compare_programs", "set_all_seeds", "time_fn",
    "CSVLogger",
]
