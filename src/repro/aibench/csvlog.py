"""CSV result logging (paper §V-A-g): one row per variant execution, with
REPRO_BENCH_*-prefixed environment variables captured as extra columns."""

from __future__ import annotations

import csv
import json
import os
import pathlib
from typing import Dict, Optional

ENV_PREFIX = "REPRO_BENCH_"


class CSVLogger:
    def __init__(self, path: pathlib.Path):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fieldnames = None

    def _env_cols(self) -> Dict[str, str]:
        return {k.lower(): v for k, v in os.environ.items()
                if k.startswith(ENV_PREFIX)}

    def log(self, *, kernel: str, backend: str, level: str = "L2",
            flops: Optional[float] = None, tflops: Optional[float] = None,
            bytes_: Optional[float] = None, gbps: Optional[float] = None,
            time_us: Optional[float] = None, dims: Optional[Dict] = None,
            note: str = "", **extra):
        row = {
            "kernel": kernel, "backend": backend, "level": level,
            "flops": flops, "tflops": tflops, "bytes": bytes_, "gbps": gbps,
            "time_us": time_us,
            "dims": json.dumps(dims or {}, sort_keys=True),
            "note": note,
        }
        row.update(extra)
        row.update(self._env_cols())
        exists = self.path.exists() and self.path.stat().st_size > 0
        with self.path.open("a", newline="") as f:
            writer = csv.DictWriter(f, fieldnames=list(row))
            if not exists:
                writer.writeheader()
            writer.writerow(row)
