"""Recurrent blocks: Mamba-2 (SSD) and RG-LRU (Griffin/RecurrentGemma).

Both expose a full-sequence ``apply_*`` (training/prefill) and a single-token
``*_step`` (decode) driven by explicit state pytrees — O(1) decode memory,
which is what makes the long_500k cells runnable for these families.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

CONV_K = 4  # short causal conv width (mamba2 / griffin convention)


# ======================================================================
# Mamba-2 block
# ======================================================================

def mamba_dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    heads = d_inner // ssm.head_dim
    return d_inner, heads, ssm.d_state, ssm.head_dim


def init_mamba_block(cfg: ModelConfig, key, dtype) -> Dict:
    d = cfg.d_model
    d_inner, h, n, p_ = mamba_dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 8)
    return {
        "wx": _dense_init(ks[0], (d, d_inner), dtype),
        "wz": _dense_init(ks[1], (d, d_inner), dtype),
        "wb": _dense_init(ks[2], (d, n), dtype),
        "wc": _dense_init(ks[3], (d, n), dtype),
        "wdt": _dense_init(ks[4], (d, h), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),            # a = -exp(a_log)
        "conv_w": _dense_init(ks[5], (CONV_K, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "wo": _dense_init(ks[6], (d_inner, d), dtype),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width CONV_K. u: [B, S, C]; w: [K, C]."""
    pad = jnp.pad(u, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(CONV_K))
    return out + b


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    y32 = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(y.dtype)


def apply_mamba_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                      use_pallas: bool = False, return_state: bool = False):
    """Full-sequence forward. x: [B, S, D]. With ``return_state`` also returns
    the serving state {conv (pre-conv tail), ssm (final SSD state)}."""
    bsz, s, d = x.shape
    d_inner, h, n, hd = mamba_dims(cfg)
    xp = x @ p["wx"]
    z = x @ p["wz"]
    bc = jnp.concatenate([x @ p["wb"], x @ p["wc"]], axis=-1)
    u_raw = jnp.concatenate([xp, bc], axis=-1)
    u = jax.nn.silu(_causal_conv(u_raw, p["conv_w"], p["conv_b"]))
    xp, b_in, c_in = jnp.split(u, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, S, H]
    a = -jnp.exp(p["a_log"])                                    # [H]
    xh = xp.reshape(bsz, s, h, hd)
    from repro.kernels.ops import ssd
    y, final_state = ssd(xh, dt, a, b_in, c_in, chunk=cfg.ssm.chunk,
                         use_pallas=use_pallas)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = y @ p["wo"]
    if return_state:
        state = {"conv": u_raw[:, -(CONV_K - 1):].astype(jnp.float32),
                 "ssm": final_state}
        return out, state
    return out


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_inner, h, n, hd = mamba_dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, hd, n), jnp.float32),
    }


def mamba_block_step(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                     state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: [B, 1, D]."""
    bsz = x.shape[0]
    d_inner, h, n, hd = mamba_dims(cfg)
    xt = x[:, 0]
    xp = xt @ p["wx"]
    z = xt @ p["wz"]
    bc = jnp.concatenate([xt @ p["wb"], xt @ p["wc"]], axis=-1)
    u = jnp.concatenate([xp, bc], axis=-1)                    # [B, conv_dim]
    window = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # [B, K, C]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(conv_out)
    xp, b_in, c_in = jnp.split(u, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus((xt @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None, :])                           # [B, H]
    xh = xp.reshape(bsz, h, hd).astype(jnp.float32)
    dbx = jnp.einsum("bhp,bn,bh->bhpn", xh, b_in.astype(jnp.float32), dt)
    ssm = state["ssm"] * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", ssm, c_in.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = (y @ p["wo"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": ssm}


# ======================================================================
# RG-LRU block (Griffin recurrent block)
# ======================================================================

RGLRU_C = 8.0


def init_rglru_block(cfg: ModelConfig, key, dtype) -> Dict:
    d = cfg.d_model
    d_rnn = d  # lru width = d_model (recurrentgemma-2b: 2560)
    ks = jax.random.split(key, 6)
    return {
        "wx": _dense_init(ks[0], (d, d_rnn), dtype),
        "wgate": _dense_init(ks[1], (d, d_rnn), dtype),
        "conv_w": _dense_init(ks[2], (CONV_K, d_rnn), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_r": _dense_init(ks[3], (d_rnn, d_rnn), dtype),
        "b_r": jnp.zeros((d_rnn,), jnp.float32),
        "w_i": _dense_init(ks[4], (d_rnn, d_rnn), dtype),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lam": jnp.full((d_rnn,), 1.0, jnp.float32),          # Λ
        "wo": _dense_init(ks[5], (d_rnn, d), dtype),
    }


def _rglru_gates(p: Dict, u: jnp.ndarray):
    r = jax.nn.sigmoid((u @ p["w_r"]).astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r          # [..., d_rnn]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    gated_in = beta * i * u.astype(jnp.float32)
    return a, gated_in


def rglru_scan(a: jnp.ndarray, gin: jnp.ndarray, h0=None,
               chunk: int = 256) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + gin_t, chunked: associative scan within chunks
    (parallel-friendly) + lax.scan across chunk boundaries. Backward memory
    is O(S/chunk) carried states + one chunk's scan levels, instead of the
    O(S log S) level pyramid of a full-length associative scan."""
    b, s, d = a.shape
    q = min(chunk, s)
    if s % q:
        return _rglru_assoc(a, gin, h0)
    nc = s // q
    ac = a.reshape(b, nc, q, d)
    gc = gin.reshape(b, nc, q, d)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inp):
        a_q, g_q = inp                                    # [B, Q, D]
        a_sc, h_in = jax.lax.associative_scan(combine, (a_q, g_q), axis=1)
        h_seq = h_in + a_sc * h[:, None, :]               # carry-in correction
        return h_seq[:, -1], h_seq

    h0 = h0 if h0 is not None else jnp.zeros((b, d), a.dtype)
    _, hs = jax.lax.scan(chunk_step, h0,
                         (jnp.moveaxis(ac, 1, 0), jnp.moveaxis(gc, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).reshape(b, s, d)


def _rglru_assoc(a, gin, h0=None):
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    a_sc, h = jax.lax.associative_scan(combine, (a, gin), axis=1)
    if h0 is not None:
        h = h + a_sc * h0[:, None, :]
    return h


def apply_rglru_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                      use_pallas: bool = False) -> jnp.ndarray:
    """Full-sequence forward via chunked linear recurrence. x: [B, S, D]."""
    gate = jax.nn.gelu(x @ p["wgate"])
    u = _causal_conv(x @ p["wx"], p["conv_w"], p["conv_b"])
    a, gin = _rglru_gates(p, u)                               # [B, S, d_rnn]
    h = rglru_scan(a, gin)
    y = (h.astype(x.dtype) * gate)
    return y @ p["wo"]


def rglru_state_init(cfg: ModelConfig, batch: int) -> Dict:
    d_rnn = cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, d_rnn), jnp.float32),
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
    }


def rglru_block_step(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                     state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: [B, 1, D]."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ p["wgate"])
    u_raw = xt @ p["wx"]
    window = jnp.concatenate([state["conv"], u_raw[:, None].astype(jnp.float32)],
                             axis=1)
    u = jnp.einsum("bkc,kc->bc", window.astype(x.dtype), p["conv_w"]) + p["conv_b"]
    a, gin = _rglru_gates(p, u)
    h = a * state["h"] + gin
    y = (h.astype(x.dtype) * gate) @ p["wo"]
    return y[:, None], {"conv": window[:, 1:], "h": h}
