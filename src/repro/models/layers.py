"""Functional model layers (pure JAX, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; init fns are PRNG-keyed and work
    under ``jax.eval_shape`` (the dry-run never materializes weights).
  * activations flow as [B, S, D]; attention heads grouped as
    [B, S, Hkv, G, Dh] (G = query heads per KV head) so GQA never has to
    materialize repeated KV.
  * every layer takes ``use_pallas`` — True routes the hot spots through the
    Pallas kernels (interpret mode on CPU); False uses the jnp path that the
    multi-pod dry-run lowers.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def shard_hint(x: jnp.ndarray, *entries) -> jnp.ndarray:
    """Best-effort sharding constraint: applies only when an ambient mesh is
    active (the launcher's ``with mesh:``), drops axis names the mesh lacks,
    and guards divisibility — so model code stays mesh-agnostic and tests on
    one device are unaffected. Entries may be None, an axis name, or a tuple
    of axis names."""
    try:
        import os
        if os.environ.get("REPRO_NO_SP"):  # perf-iteration variant (§Perf)
            return x
        from jax._src import mesh as mesh_lib
        env = mesh_lib.thread_resources.env.physical_mesh
        if env.empty:
            return x
        names = set(env.axis_names)
        clean = []
        for dim, e in enumerate(entries):
            if e is None:
                clean.append(None)
                continue
            axes = e if isinstance(e, tuple) else (e,)
            axes = tuple(a for a in axes if a in names)
            size = 1
            for a in axes:
                size *= env.shape[a]
            if not axes or size <= 1 or x.shape[dim] % size != 0:
                clean.append(None)
            else:
                clean.append(axes if len(axes) > 1 else axes[0])
        if all(c is None for c in clean):
            return x
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*clean))
    except Exception:  # noqa: BLE001 — hints must never break execution
        return x


# ======================================================================
# norms
# ======================================================================

def init_norm(cfg: ModelConfig, dtype) -> Dict:
    if cfg.non_parametric_ln:
        return {}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
               use_pallas: bool = False) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + 1e-5)
    else:
        if use_pallas and x.ndim >= 2 and not cfg.non_parametric_ln:
            from repro.kernels.ops import rms_norm
            return rms_norm(x, p["scale"])
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + 1e-6)
    if not cfg.non_parametric_ln and "scale" in p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ======================================================================
# rotary embeddings
# ======================================================================

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, Dh]; positions: [S]. Trailing-dim broadcasting aligns the
    [S, Dh/2] angle table against any leading batch/head dims."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ======================================================================
# attention (GQA, qk-norm, bias, local window, cross)
# ======================================================================

def init_attention(cfg: ModelConfig, key, dtype, cross: bool = False) -> Dict:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh), dtype),
        "wk": _dense_init(ks[1], (d, hkv * dh), dtype),
        "wv": _dense_init(ks[2], (d, hkv * dh), dtype),
        "wo": _dense_init(ks[3], (h * dh, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _qk_normalize(q, scale):
    var = jnp.mean(jnp.square(q.astype(jnp.float32)), axis=-1, keepdims=True)
    return (q.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
            * scale.astype(jnp.float32)).astype(q.dtype)


def _project_qkv(cfg: ModelConfig, p: Dict, x, kv_x, positions, kv_positions,
                 use_rope: bool):
    b, sq, d = x.shape
    skv = kv_x.shape[1]
    dh = cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.kv_heads
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, h, dh)
    k = k.reshape(b, skv, hkv, dh)
    v = v.reshape(b, skv, hkv, dh)
    if cfg.qk_norm:
        q = _qk_normalize(q, p["q_norm"])
        k = _qk_normalize(k, p["k_norm"])
    if use_rope:
        q = rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        k = rope(k.swapaxes(1, 2), kv_positions, cfg.rope_theta).swapaxes(1, 2)
    return q, k, v


def grouped_attention(q, k, v, *, causal: bool, window: Optional[int],
                      q_offset: int = 0, kv_chunk: int = 1024,
                      q_chunk: int = 2048, chunked: bool = True) -> jnp.ndarray:
    import os as _os
    if _os.environ.get("REPRO_ATTN_CHUNK"):  # §Perf iteration variant
        kv_chunk = q_chunk = int(_os.environ["REPRO_ATTN_CHUNK"])
    """Memory-efficient grouped attention.

    q: [B, Sq, H, Dh]; k, v: [B, Skv, Hkv, Dh]. Returns [B, Sq, H, Dh].
    ``chunked`` runs the Flash-Attention recurrence at the XLA level: an
    outer serial map over q blocks, an inner scan over KV blocks with running
    max/sum — peak score memory is O(q_chunk x kv_chunk), never S x S.
    Required for the 32k/500k shapes, and the jnp mirror of the Pallas flash
    kernel.
    """
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)

    def mask_for(qpos, kpos):
        m = None
        if causal:
            m = kpos[None, :] <= qpos[:, None]
        if window is not None:
            w = kpos[None, :] > (qpos[:, None] - window)
            m = w if m is None else (m & w)
        return m

    if not chunked or skv <= kv_chunk:
        qg = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32) * scale
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k32)
        m = mask_for(jnp.arange(sq) + q_offset, jnp.arange(skv))
        if m is not None:
            s = jnp.where(m[None, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", pr, v32)
        return out.reshape(b, sq, h, dh).astype(q.dtype)

    nkv = -(-skv // kv_chunk)
    kpad = nkv * kv_chunk - skv
    if kpad:
        k32 = jnp.pad(k32, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v32 = jnp.pad(v32, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    kc = k32.reshape(b, nkv, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v32.reshape(b, nkv, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)

    q_chunk = min(q_chunk, sq)
    nq = -(-sq // q_chunk)
    qpad = nq * q_chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0))) if qpad else q
    qb = qp.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)

    def one_q_block(args):
        qi, qblk = args
        qg = qblk.astype(jnp.float32) * scale          # [b, qc, hkv, g, dh]
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        @partial(jax.checkpoint, prevent_cse=False)
        def step(carry, inp):
            # checkpointed: backward recomputes the probability tile instead
            # of saving O(S^2) residuals (the flash-backward memory property)
            m_run, l_run, acc = carry
            idx, kb, vb = inp
            kpos = idx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb)
            msk = (kpos < skv)[None, :]
            mm = mask_for(qpos, kpos)
            if mm is not None:
                msk = msk & mm
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_run, m_cur)
            pr = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(pr, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", pr, vb)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (jnp.arange(nkv), kc, vc))
        l_f = jnp.where(l_f == 0.0, 1.0, l_f)
        return (acc / l_f[..., None]).transpose(0, 3, 1, 2, 4)  # [b,qc,hkv,g,dh]

    if nq == 1:
        out = one_q_block((jnp.asarray(0), qb[0]))
    else:
        outs = jax.lax.map(one_q_block, (jnp.arange(nq), qb))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_chunk, hkv, g, dh)
        out = out[:, :sq] if qpad else out
        return out.reshape(b, sq, h, dh).astype(q.dtype)
    out = out[:, :sq] if qpad else out
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def apply_attention(cfg: ModelConfig, p: Dict, x, *,
                    kv_x=None, positions=None, kv_positions=None,
                    causal=True, window=None, use_rope=True,
                    use_pallas: bool = False, chunked: bool = True):
    """Self- (kv_x=None) or cross-attention over full sequences."""
    b, sq, d = x.shape
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    positions = positions if positions is not None else jnp.arange(sq)
    kv_positions = kv_positions if kv_positions is not None else jnp.arange(skv)
    q, k, v = _project_qkv(cfg, p, x, kv_x, positions, kv_positions, use_rope)
    if use_pallas:
        from repro.kernels.ops import attention as pallas_attention
        out = pallas_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=causal,
                               window=window)
        out = out.transpose(0, 2, 1, 3)
    else:
        out = grouped_attention(q, k, v, causal=causal, window=window,
                                q_offset=skv - sq, chunked=chunked)
    return out.reshape(b, sq, cfg.num_heads * cfg.resolved_head_dim) @ p["wo"]


KV_Q_SCALE = 32.0  # fixed-point scale for int8 KV caches (serving option)


def _kv_quant(x, dtype):
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_Q_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def _kv_dequant(x):
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) / KV_Q_SCALE
    return x.astype(jnp.float32)


def decode_attention_step(cfg: ModelConfig, p: Dict, x, cache_k, cache_v,
                          position, *, window=None, use_rope=True,
                          use_pallas: bool = False):
    """One-token decode. x: [B, 1, D]; cache_k/v: [B, Smax, Hkv, Dh] (bf16 or
    int8 fixed-point); position: scalar index of the new token. Returns
    (out, new_k, new_v)."""
    b, _, d = x.shape
    smax = cache_k.shape[1]
    pos_arr = jnp.full((1,), position)
    q, k_new, v_new = _project_qkv(cfg, p, x, x, pos_arr, pos_arr, use_rope)
    k_new = _kv_quant(k_new, cache_k.dtype)
    v_new = _kv_quant(v_new, cache_v.dtype)
    if window is not None and smax == window:
        # rolling window cache: shift left, append at the end
        cache_k = jnp.concatenate([cache_k[:, 1:], k_new], axis=1)
        cache_v = jnp.concatenate([cache_v[:, 1:], v_new], axis=1)
        lengths = jnp.minimum(position + 1, window)
        kpos_last = position
        valid = (jnp.arange(smax) > (smax - 1 - lengths))
        k_eff, v_eff = cache_k, cache_v
        length_mask = valid
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, position, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, position, 1)
        k_eff, v_eff = cache_k, cache_v
        length_mask = jnp.arange(smax) <= position
    if use_pallas and window is None:
        from repro.kernels.ops import decode_attn
        qh = q.reshape(b, cfg.num_heads, cfg.resolved_head_dim)
        k_eff = _kv_dequant(k_eff) if k_eff.dtype == jnp.int8 else k_eff
        v_eff = _kv_dequant(v_eff) if v_eff.dtype == jnp.int8 else v_eff
        out = decode_attn(qh, k_eff.transpose(0, 2, 1, 3),
                          v_eff.transpose(0, 2, 1, 3),
                          lengths=jnp.full((b,), position + 1, jnp.int32))
        out = out.reshape(b, 1, -1)
    else:
        h, hkv = cfg.num_heads, cfg.kv_heads
        dh = cfg.resolved_head_dim
        g = h // hkv
        qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) / math.sqrt(dh)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, _kv_dequant(k_eff))
        s = jnp.where(length_mask[None, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", pr, _kv_dequant(v_eff))
        out = out.reshape(b, 1, h * dh).astype(x.dtype)
    return out @ p["wo"], cache_k, cache_v


# ======================================================================
# MLP / MoE
# ======================================================================

def init_mlp(cfg: ModelConfig, key, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": _dense_init(ks[0], (d, f), dtype),
         "wo": _dense_init(ks[1], (f, d), dtype)}
    if cfg.glu:
        p["wg"] = _dense_init(ks[2], (d, f), dtype)
    return p


def _act(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.activation == "silu" else jax.nn.gelu(x)


def apply_mlp(cfg: ModelConfig, p: Dict, x, use_pallas: bool = False):
    h = _act(cfg, x @ p["wi"])
    if cfg.glu:
        h = h * (x @ p["wg"])
    return h @ p["wo"]


def init_moe(cfg: ModelConfig, key, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e), dtype),
        "wi": _dense_init(ks[1], (e, d, f), dtype),
        "wg": _dense_init(ks[2], (e, d, f), dtype),
        "wo": _dense_init(ks[3], (e, f, d), dtype),
    }


def moe_route(cfg: ModelConfig, p: Dict, xt: jnp.ndarray,
              capacity_factor: float = 1.25):
    """Top-k routing with static expert capacity. Returns
    (flat_expert [T*K], slot [T*K], keep [T*K], gates [T, K], capacity)."""
    t, d = xt.shape
    e, top_k = cfg.moe.num_experts, cfg.moe.top_k
    logits = (xt @ p["router"]).astype(jnp.float32)        # [T, E]
    gate_vals, idx = jax.lax.top_k(logits, top_k)          # [T, K]
    gates = jax.nn.softmax(gate_vals, axis=-1)
    capacity = max(1, int(capacity_factor * t * top_k / e))
    flat_e = idx.reshape(-1)                               # [T*K]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # [T*K, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1              # position per expert
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < capacity
    return flat_e, jnp.where(keep, slot, capacity - 1), keep, gates, capacity


def apply_moe(cfg: ModelConfig, p: Dict, x, use_pallas: bool = False,
              capacity_factor: float = 1.25):
    """GShard-style *grouped* capacity dispatch: tokens are routed
    independently within each batch group (group = one batch row), so the
    cumsum/scatter/gather machinery is vmapped over a dim that is sharded
    over DP — no cross-shard prefix sums, no replicated dispatch buffers
    under SPMD. Over-capacity tokens drop (standard). The EP all-to-all
    variant lives in sharding/expert_parallel.py."""
    b, s, d = x.shape
    e, top_k = cfg.moe.num_experts, cfg.moe.top_k
    tg = s                                        # tokens per group
    capacity = max(1, int(capacity_factor * tg * top_k / e))
    xg = x                                        # [G=b, Tg=s, D]

    def route_group(xt):                          # [Tg, D] local to one group
        logits = (xt @ p["router"]).astype(jnp.float32)
        gate_vals, idx = jax.lax.top_k(logits, top_k)
        gates = jax.nn.softmax(gate_vals, axis=-1)
        flat_e = idx.reshape(-1)                  # [Tg*K]
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = slot < capacity
        slot = jnp.where(keep, slot, capacity - 1)
        keep_f = keep.astype(xt.dtype)[:, None]
        xr = jnp.repeat(xt, top_k, axis=0) * keep_f
        buf = jnp.zeros((e, capacity, d), xt.dtype).at[flat_e, slot].add(xr)
        return buf, flat_e, slot, keep_f, gates

    buf, flat_e, slot, keep_f, gates = jax.vmap(route_group)(xg)
    # pin activation shardings: G over DP, F over model. Without these, the
    # FSDP data-sharding on the weights' contraction dim makes SPMD regather
    # the G dim (21 GB/device hidden tensors on grok) instead of the weights.
    dp = ("pod", "data")
    buf = shard_hint(buf, dp, None, None, None)            # [G, E, C, D]
    hi = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    hi = shard_hint(hi, dp, None, None, "model")
    hg = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    hg = shard_hint(hg, dp, None, None, "model")
    hh = _act(cfg, hi) * hg
    out = jnp.einsum("gecf,efd->gecd", hh, p["wo"])        # [G, E, C, D]
    out = shard_hint(out, dp, None, None, None)

    def combine_group(out_g, fe, sl, kf, gt):
        gathered = out_g[fe, sl] * kf                      # [Tg*K, D]
        return (gathered.reshape(tg, top_k, d)
                * gt.astype(out_g.dtype)[..., None]).sum(axis=1)

    y = jax.vmap(combine_group)(out, flat_e, slot, keep_f, gates)
    return y.reshape(b, s, d)


def moe_aux_loss(cfg: ModelConfig, p: Dict, x) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss."""
    b, s, d = x.shape
    e = cfg.moe.num_experts
    logits = (x.reshape(-1, d) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    return e * jnp.sum(frac * jnp.mean(probs, axis=0))
