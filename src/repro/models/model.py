"""Unified model API over the five architecture families.

  init_params(cfg, key, dtype)                      -> params pytree
  forward(cfg, params, batch, flags)                -> (logits, aux_loss)
  init_cache(cfg, batch, max_len, dtype)            -> cache pytree
  prefill(cfg, params, batch, flags)                -> (last_logits, cache)
  decode_step(cfg, params, cache, tokens, pos, flags) -> (logits, cache)

Families: dense / moe (scan-over-layers), ssm (mamba2, scan), hybrid
(recurrentgemma, per-layer loop over the block pattern), encdec (whisper,
scan per stack). ``batch`` may carry ``prefix_embeds`` (VLM patch stub) or
``frames`` (audio frame stub) per the assignment's frontend-stub rule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import recurrent as R


@dataclasses.dataclass(frozen=True)
class RuntimeFlags:
    use_pallas: bool = False
    chunked_attention: bool = True
    remat: bool = True
    scan_layers: bool = True
    moe_capacity_factor: float = 1.25  # GShard default; tests may raise it
    loss_chunks: int = 8               # streamed-CE chunks (1 = monolithic)


DEFAULT_FLAGS = RuntimeFlags()


# ======================================================================
# init
# ======================================================================

def _init_dense_layer(cfg: ModelConfig, key, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg, dtype),
         "attn": L.init_attention(cfg, ks[0], dtype),
         "ln2": L.init_norm(cfg, dtype)}
    if cfg.moe:
        p["moe"] = L.init_moe(cfg, ks[1], dtype)
    else:
        p["mlp"] = L.init_mlp(cfg, ks[1], dtype)
    return p


def _init_encdec_layer(cfg: ModelConfig, key, dtype, decoder: bool) -> Dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg, dtype),
         "attn": L.init_attention(cfg, ks[0], dtype),
         "ln2": L.init_norm(cfg, dtype),
         "mlp": L.init_mlp(cfg, ks[1], dtype)}
    if decoder:
        p["ln_x"] = L.init_norm(cfg, dtype)
        p["xattn"] = L.init_attention(cfg, ks[2], dtype, cross=True)
    return p


def _init_hybrid_layer(cfg: ModelConfig, key, dtype, kind: str) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"ln1": L.init_norm(cfg, dtype), "ln2": L.init_norm(cfg, dtype),
         "kind": kind,
         "mlp": L.init_mlp(cfg, ks[1], dtype)}
    if kind == "attn":
        p["attn"] = L.init_attention(cfg, ks[0], dtype)
    else:
        p["rglru"] = R.init_rglru_block(cfg, ks[0], dtype)
    return p


def hybrid_pattern(cfg: ModelConfig):
    pat = cfg.block_pattern or ("attn",)
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype),
        "ln_f": L.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L._dense_init(keys[1], (cfg.d_model, cfg.vocab), dtype)

    if cfg.family in ("dense", "moe"):
        lkeys = jax.random.split(keys[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_dense_layer(cfg, k, dtype))(lkeys)
    elif cfg.family == "ssm":
        lkeys = jax.random.split(keys[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: {"ln1": L.init_norm(cfg, dtype),
                       "mamba": R.init_mamba_block(cfg, k, dtype)})(lkeys)
    elif cfg.family == "hybrid":
        lkeys = jax.random.split(keys[2], cfg.num_layers)
        kinds = hybrid_pattern(cfg)
        params["layers"] = [
            {k: v for k, v in _init_hybrid_layer(cfg, lkeys[i], dtype,
                                                 kinds[i]).items()
             if k != "kind"}
            for i in range(cfg.num_layers)]
    elif cfg.family == "encdec":
        ekeys = jax.random.split(keys[3], cfg.encoder_layers)
        dkeys = jax.random.split(keys[4], cfg.num_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_encdec_layer(cfg, k, dtype, decoder=False))(ekeys)
        params["layers"] = jax.vmap(
            lambda k: _init_encdec_layer(cfg, k, dtype, decoder=True))(dkeys)
        params["enc_ln_f"] = L.init_norm(cfg, dtype)
        maxp = min(cfg.max_seq_len, 32768)
        params["pos_embed"] = (jax.random.normal(
            keys[5], (maxp, cfg.d_model), jnp.float32) * 0.01).astype(dtype)
    else:
        raise ValueError(cfg.family)
    return params


# ======================================================================
# embedding / unembedding
# ======================================================================

def _embed(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    from repro.sharding.rules import gather_fsdp
    tokens = batch["tokens"]
    x = jnp.take(gather_fsdp({"embed": params["embed"]})["embed"],
                 tokens, axis=0)
    if cfg.num_prefix_embeds and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    return x


def _unembed(cfg: ModelConfig, params, x) -> jnp.ndarray:
    from repro.sharding.rules import gather_fsdp
    if cfg.tie_embeddings:
        w = gather_fsdp({"embed": params["embed"]})["embed"].T
    else:
        w = gather_fsdp({"unembed": params["unembed"]})["unembed"]
    return (x @ w).astype(jnp.float32)


# ======================================================================
# blocks
# ======================================================================

def _dense_block(cfg: ModelConfig, flags: RuntimeFlags, x, layer,
                 causal=True, window=None, use_rope=True):
    from repro.sharding.rules import gather_fsdp
    layer = gather_fsdp(layer)
    h = L.apply_attention(cfg, layer["attn"],
                          L.apply_norm(cfg, layer["ln1"], x, flags.use_pallas),
                          causal=causal, window=window, use_rope=use_rope,
                          use_pallas=flags.use_pallas,
                          chunked=flags.chunked_attention)
    x = x + h
    inner = L.apply_norm(cfg, layer["ln2"], x, flags.use_pallas)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        y = L.apply_moe(cfg, layer["moe"], inner, flags.use_pallas,
                        capacity_factor=flags.moe_capacity_factor)
        aux = L.moe_aux_loss(cfg, layer["moe"], inner)
    else:
        y = L.apply_mlp(cfg, layer["mlp"], inner, flags.use_pallas)
    # sequence-parallel residual carry: what the layer scan saves for
    # backward is S-sharded over the model axis
    return L.shard_hint(x + y, ("pod", "data"), "model", None), aux


def _scan_blocks(cfg, flags, x, layers_params, block_fn):
    def body(carry, layer):
        h, aux = carry
        h2, a = block_fn(h, layer)
        return (h2, aux + a), None
    if flags.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               layers_params)
    return x, aux


# ======================================================================
# forward (training / scoring)
# ======================================================================

def forward(cfg: ModelConfig, params, batch: Dict, flags: RuntimeFlags =
            DEFAULT_FLAGS) -> Tuple[jnp.ndarray, jnp.ndarray]:
    hidden, aux = forward_hidden(cfg, params, batch, flags)
    return _unembed(cfg, params, hidden), aux


def forward_hidden(cfg: ModelConfig, params, batch: Dict,
                   flags: RuntimeFlags = DEFAULT_FLAGS
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # backbone up to (and including) the final norm; the unembed stays
    # outside so the loss can stream it over sequence chunks
    if cfg.family == "encdec":
        return _forward_encdec(cfg, params, batch, flags)
    x = _embed(cfg, params, batch)
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        x, aux = _scan_blocks(
            cfg, flags, x, params["layers"],
            lambda h, layer: _dense_block(cfg, flags, h, layer,
                                          causal=True, window=cfg.window))
    elif cfg.family == "ssm":
        def block(h, layer):
            from repro.sharding.rules import gather_fsdp
            layer = gather_fsdp(layer)
            inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
            out = h + R.apply_mamba_block(cfg, layer["mamba"], inner,
                                          flags.use_pallas)
            return (L.shard_hint(out, ("pod", "data"), "model", None),
                    jnp.zeros((), jnp.float32))
        x, aux = _scan_blocks(cfg, flags, x, params["layers"], block)
    elif cfg.family == "hybrid":
        kinds = hybrid_pattern(cfg)

        def hybrid_layer(h, layer, kind):
            from repro.sharding.rules import gather_fsdp
            layer = gather_fsdp(layer)
            inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
            if kind == "attn":
                mix = L.apply_attention(cfg, layer["attn"], inner, causal=True,
                                        window=cfg.window,
                                        use_pallas=flags.use_pallas,
                                        chunked=flags.chunked_attention)
            else:
                mix = R.apply_rglru_block(cfg, layer["rglru"], inner,
                                          flags.use_pallas)
            h = h + mix
            h = h + L.apply_mlp(cfg, layer["mlp"],
                                L.apply_norm(cfg, layer["ln2"], h,
                                             flags.use_pallas),
                                flags.use_pallas)
            return L.shard_hint(h, ("pod", "data"), "model", None)

        if flags.remat:
            # NOTE: prevent_cse must stay True here — the hybrid stack is an
            # unrolled python loop, and CSE would merge the rematerialized
            # values back with the forward ones, undoing the checkpoint
            # (prevent_cse=False is only safe inside scan bodies).
            hybrid_layer = jax.checkpoint(hybrid_layer, static_argnums=(2,))
        for i, layer in enumerate(params["layers"]):
            x = hybrid_layer(x, layer, kinds[i])
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["ln_f"], x, flags.use_pallas)
    return x, aux


def _encode(cfg, params, batch, flags):
    enc = batch["frames"].astype(params["embed"].dtype)  # stub frontend output
    f = enc.shape[1]
    pos = jnp.arange(f)
    sin = _sinusoidal(pos, cfg.d_model).astype(enc.dtype)
    enc = enc + sin

    def enc_block(h, layer):
        return _dense_block(cfg, flags, h, layer, causal=False,
                            use_rope=False)[0], jnp.zeros((), jnp.float32)

    def body(carry, layer):
        h, aux = carry
        h2, a = enc_block(h, layer)
        return (h2, aux), None
    if flags.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (enc, _), _ = jax.lax.scan(body, (enc, jnp.zeros((), jnp.float32)),
                               params["enc_layers"])
    return L.apply_norm(cfg, params["enc_ln_f"], enc, flags.use_pallas)


def _decoder_block(cfg, flags, x, enc_out, layer):
    from repro.sharding.rules import gather_fsdp
    layer = gather_fsdp(layer)
    h = L.apply_attention(cfg, layer["attn"],
                          L.apply_norm(cfg, layer["ln1"], x, flags.use_pallas),
                          causal=True, use_rope=False,
                          use_pallas=flags.use_pallas,
                          chunked=flags.chunked_attention)
    x = x + h
    h = L.apply_attention(cfg, layer["xattn"],
                          L.apply_norm(cfg, layer["ln_x"], x, flags.use_pallas),
                          kv_x=enc_out, causal=False, use_rope=False,
                          use_pallas=flags.use_pallas,
                          chunked=flags.chunked_attention)
    x = x + h
    x = x + L.apply_mlp(cfg, layer["mlp"],
                        L.apply_norm(cfg, layer["ln2"], x, flags.use_pallas),
                        flags.use_pallas)
    return L.shard_hint(x, ("pod", "data"), "model", None)


def _forward_encdec(cfg, params, batch, flags):
    enc_out = _encode(cfg, params, batch, flags)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], 0, s, 0)

    def body(h, layer):
        return _decoder_block(cfg, flags, h, enc_out, layer), None
    if flags.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["ln_f"], x, flags.use_pallas)
    return x, jnp.zeros((), jnp.float32)


def _sinusoidal(pos, d):
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = pos.astype(jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ======================================================================
# loss
# ======================================================================

def lm_loss(cfg: ModelConfig, params, batch, flags: RuntimeFlags = DEFAULT_FLAGS,
            aux_weight: float = 0.01) -> jnp.ndarray:
    hidden, aux = forward_hidden(cfg, params, batch, flags)
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:  # vlm prefix positions carry no loss
        hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]
    b, s, _ = hidden.shape
    # streamed cross-entropy: the [B, S, V] logits tensor never materializes
    # in full — essential for the 150k-256k-vocab archs. Checkpointed scan
    # over sequence chunks; backward recomputes each chunk's logits.
    nc = flags.loss_chunks
    if nc <= 1 or s % nc != 0:
        nc = 1
    hs = jnp.moveaxis(hidden.reshape(b, nc, s // nc, hidden.shape[-1]), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, s // nc), 1, 0)

    def chunk(carry, inp):
        tot, cnt = carry
        h, lab = inp
        logits = _unembed(cfg, params, h)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        return (tot + jnp.sum(nll * mask), cnt + jnp.sum(mask)), None

    body = jax.checkpoint(chunk, prevent_cse=False) if nc > 1 else chunk
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux_weight * aux


# ======================================================================
# caches + decode
# ======================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    dh = cfg.resolved_head_dim
    if cfg.family in ("dense", "moe"):
        shape = (cfg.num_layers, batch, max_len, cfg.kv_heads, dh)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if cfg.family == "ssm":
        st = R.mamba_state_init(cfg, batch)
        return {"layers": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), st)}
    if cfg.family == "hybrid":
        kinds = hybrid_pattern(cfg)
        w = min(cfg.window or max_len, max_len)
        cache = {}
        for i, kind in enumerate(kinds):
            if kind == "attn":
                cache[f"layer_{i}"] = {
                    "k": jnp.zeros((batch, w, cfg.kv_heads, dh), dtype),
                    "v": jnp.zeros((batch, w, cfg.kv_heads, dh), dtype)}
            else:
                cache[f"layer_{i}"] = R.rglru_state_init(cfg, batch)
        return cache
    if cfg.family == "encdec":
        shape = (cfg.num_layers, batch, max_len, cfg.kv_heads, dh)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "xk": None, "xv": None}  # cross-cache filled by prefill
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens: jnp.ndarray,
                position, flags: RuntimeFlags = DEFAULT_FLAGS
                ) -> Tuple[jnp.ndarray, Dict]:
    """One new token against the cache. tokens: [B, 1]; position: scalar."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "encdec":
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], position, 1, 0)
        x = x + pe

    if cfg.family in ("dense", "moe", "encdec"):
        def body(h, inp):
            layer, ck, cv = inp[0], inp[1], inp[2]
            inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
            out, ck, cv = L.decode_attention_step(
                cfg, layer["attn"], inner, ck, cv, position,
                window=cfg.window, use_rope=cfg.family != "encdec",
                use_pallas=flags.use_pallas)
            h = h + out
            if cfg.family == "encdec":
                inner = L.apply_norm(cfg, layer["ln_x"], h, flags.use_pallas)
                h = h + _cross_decode(cfg, layer, inner, inp[3], inp[4])
            inner = L.apply_norm(cfg, layer["ln2"], h, flags.use_pallas)
            if cfg.moe:
                h = h + L.apply_moe(cfg, layer["moe"], inner, flags.use_pallas,
                                    capacity_factor=max(
                                        flags.moe_capacity_factor, 2.0))
            else:
                h = h + L.apply_mlp(cfg, layer["mlp"], inner, flags.use_pallas)
            return h, (ck, cv)

        xs = (params["layers"], cache["k"], cache["v"])
        if cfg.family == "encdec":
            xs = xs + (cache["xk"], cache["xv"])
        x, (k_new, v_new) = jax.lax.scan(lambda h, inp: body(h, inp), x, xs)
        cache = dict(cache, k=k_new, v=v_new)

    elif cfg.family == "ssm":
        def body(h, inp):
            layer, st = inp
            inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
            out, st = R.mamba_block_step(cfg, layer["mamba"], inner, st)
            return h + out, st
        x, new_states = jax.lax.scan(body, x, (params["layers"],
                                               cache["layers"]))
        cache = dict(cache, layers=new_states)

    elif cfg.family == "hybrid":
        kinds = hybrid_pattern(cfg)
        cache = dict(cache)
        for i, layer in enumerate(params["layers"]):
            entry = cache[f"layer_{i}"]
            inner = L.apply_norm(cfg, layer["ln1"], x, flags.use_pallas)
            if kinds[i] == "attn":
                out, ck, cv = L.decode_attention_step(
                    cfg, layer["attn"], inner, entry["k"], entry["v"],
                    position, window=cfg.window, use_pallas=flags.use_pallas)
                cache[f"layer_{i}"] = {"k": ck, "v": cv}
            else:
                out, st = R.rglru_block_step(cfg, layer["rglru"], inner, entry)
                cache[f"layer_{i}"] = st
            x = x + out
            x = x + L.apply_mlp(cfg, layer["mlp"],
                                L.apply_norm(cfg, layer["ln2"], x,
                                             flags.use_pallas),
                                flags.use_pallas)
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["ln_f"], x, flags.use_pallas)
    return _unembed(cfg, params, x)[:, 0], cache


def _cross_decode(cfg, layer, x, xk, xv):
    """Cross-attention against the prefill-cached encoder KV."""
    import math as _m
    p = layer["xattn"]
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.kv_heads
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) / _m.sqrt(dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, xk.astype(jnp.float32))
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, xv.astype(jnp.float32))
    return (out.reshape(b, 1, h * dh).astype(x.dtype)) @ p["wo"]


def prefill(cfg: ModelConfig, params, batch: Dict,
            flags: RuntimeFlags = DEFAULT_FLAGS) -> Tuple[jnp.ndarray, Dict]:
    """Full-context forward that also returns the serving cache."""
    if cfg.family in ("ssm", "hybrid"):
        return _prefill_recurrent(cfg, params, batch, flags)
    if cfg.family == "encdec":
        return _prefill_encdec(cfg, params, batch, flags)

    x = _embed(cfg, params, batch)
    s = x.shape[1]

    def body(h, layer):
        from repro.sharding.rules import gather_fsdp
        layer = gather_fsdp(layer)
        inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
        pos = jnp.arange(s)
        q, k, v = L._project_qkv(cfg, layer["attn"], inner, inner, pos, pos,
                                 True)
        out = L.grouped_attention(q, k, v, causal=True, window=cfg.window,
                                  chunked=flags.chunked_attention)
        h = h + out.reshape(*out.shape[:2], -1) @ layer["attn"]["wo"]
        inner = L.apply_norm(cfg, layer["ln2"], h, flags.use_pallas)
        if cfg.moe:
            h = h + L.apply_moe(cfg, layer["moe"], inner, flags.use_pallas,
                                capacity_factor=flags.moe_capacity_factor)
        else:
            h = h + L.apply_mlp(cfg, layer["mlp"], inner, flags.use_pallas)
        return h, (k, v)

    if flags.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["ln_f"], x[:, -1:], flags.use_pallas)
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, {"k": ks, "v": vs}


def _prefill_recurrent(cfg, params, batch, flags):
    """SSM / hybrid prefill: run the sequence, keep final states (and the
    rolling attention window for hybrid)."""
    x = _embed(cfg, params, batch)
    bsz, s, _ = x.shape
    if cfg.family == "ssm":
        def body(h, layer):
            inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
            out, state = R.apply_mamba_block(cfg, layer["mamba"], inner,
                                             flags.use_pallas,
                                             return_state=True)
            return h + out, state
        if flags.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, states = jax.lax.scan(body, x, params["layers"])
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:], flags.use_pallas)
        return _unembed(cfg, params, x)[:, 0], {"layers": states}

    # hybrid
    kinds = hybrid_pattern(cfg)
    cache = {}
    w = min(cfg.window or s, s)
    for i, layer in enumerate(params["layers"]):
        inner = L.apply_norm(cfg, layer["ln1"], x, flags.use_pallas)
        if kinds[i] == "attn":
            pos = jnp.arange(s)
            q, k, v = L._project_qkv(cfg, layer["attn"], inner, inner, pos,
                                     pos, True)
            out = L.grouped_attention(q, k, v, causal=True, window=cfg.window,
                                      chunked=flags.chunked_attention)
            x = x + out.reshape(*out.shape[:2], -1) @ layer["attn"]["wo"]
            cache[f"layer_{i}"] = {"k": k[:, -w:], "v": v[:, -w:]}
        else:
            gate = jax.nn.gelu(inner @ layer["rglru"]["wgate"])
            u_raw = inner @ layer["rglru"]["wx"]
            u = R._causal_conv(u_raw, layer["rglru"]["conv_w"],
                               layer["rglru"]["conv_b"])
            a, gin = R._rglru_gates(layer["rglru"], u)
            hseq = R.rglru_scan(a, gin)
            x = x + (hseq.astype(x.dtype) * gate) @ layer["rglru"]["wo"]
            cache[f"layer_{i}"] = {
                "conv": u_raw[:, -(R.CONV_K - 1):].astype(jnp.float32),
                "h": hseq[:, -1]}
        x = x + L.apply_mlp(cfg, layer["mlp"],
                            L.apply_norm(cfg, layer["ln2"], x,
                                         flags.use_pallas),
                            flags.use_pallas)
    x = L.apply_norm(cfg, params["ln_f"], x[:, -1:], flags.use_pallas)
    return _unembed(cfg, params, x)[:, 0], cache


def _prefill_encdec(cfg, params, batch, flags):
    enc_out = _encode(cfg, params, batch, flags)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], 0, s, 0)
    dh = cfg.resolved_head_dim

    def body(h, layer):
        inner = L.apply_norm(cfg, layer["ln1"], h, flags.use_pallas)
        pos = jnp.arange(s)
        q, k, v = L._project_qkv(cfg, layer["attn"], inner, inner, pos, pos,
                                 False)
        out = L.grouped_attention(q, k, v, causal=True, window=None,
                                  chunked=flags.chunked_attention)
        h = h + out.reshape(*out.shape[:2], -1) @ layer["attn"]["wo"]
        inner = L.apply_norm(cfg, layer["ln_x"], h, flags.use_pallas)
        p = layer["xattn"]
        fpos = jnp.arange(enc_out.shape[1])
        qx, kx, vx = L._project_qkv(cfg, p, inner, enc_out,
                                    jnp.arange(s), fpos, False)
        xout = L.grouped_attention(qx, kx, vx, causal=False, window=None,
                                   chunked=flags.chunked_attention)
        h = h + xout.reshape(*xout.shape[:2], -1) @ p["wo"]
        h = h + L.apply_mlp(cfg, layer["mlp"],
                            L.apply_norm(cfg, layer["ln2"], h,
                                         flags.use_pallas),
                            flags.use_pallas)
        return h, (k, v, kx, vx)

    if flags.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["ln_f"], x[:, -1:], flags.use_pallas)
    return _unembed(cfg, params, x)[:, 0], {"k": ks, "v": vs,
                                            "xk": xks, "xv": xvs}
