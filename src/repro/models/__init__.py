from repro.models.model import (RuntimeFlags, DEFAULT_FLAGS, init_params,
                                forward, lm_loss, init_cache, prefill,
                                decode_step)

__all__ = ["RuntimeFlags", "DEFAULT_FLAGS", "init_params", "forward",
           "lm_loss", "init_cache", "prefill", "decode_step"]
