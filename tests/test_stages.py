"""Stage registry: validation (cycles, unknown deps), deterministic topo
order, derived planner/proposer/issue surfaces, third-party registration,
and the CI consistency gate."""

import pytest

from repro.core.issues import Issue, ISSUE_TO_STAGE, register_issue_type
from repro.core.planner import DEFAULT_ORDER, HARD_DEPS, plan
from repro.core.stages import (DEFAULT_REGISTRY, StageRegistry,
                               StageRegistryError, StageSpec)

PAPER_ORDER = ["algorithmic", "discovery", "dtype_fix", "fusion",
               "memory_access", "block_pointers", "persistent_kernel",
               "gpu_specific", "autotuning"]


# ---------------------------------------------------------------------------
# registry construction + validation
# ---------------------------------------------------------------------------

def test_default_registry_matches_paper_order():
    assert DEFAULT_REGISTRY.default_order() == PAPER_ORDER
    assert list(DEFAULT_REGISTRY.names()) == PAPER_ORDER


def test_derived_planner_constants_match_registry():
    assert DEFAULT_ORDER == PAPER_ORDER
    assert sorted(HARD_DEPS) == sorted(DEFAULT_REGISTRY.dep_pairs())


def test_duplicate_registration_rejected():
    reg = StageRegistry()
    reg.register(StageSpec(name="a"))
    with pytest.raises(StageRegistryError, match="already registered"):
        reg.register(StageSpec(name="a"))
    reg.register(StageSpec(name="a", doc="v2"), replace=True)
    assert reg.get("a").doc == "v2"


def test_self_dependency_rejected():
    with pytest.raises(StageRegistryError, match="depends on itself"):
        StageSpec(name="a", deps=("a",))


def test_unknown_dep_rejected():
    reg = StageRegistry()
    reg.register(StageSpec(name="a", deps=("ghost",)))
    with pytest.raises(StageRegistryError, match="unknown stage 'ghost'"):
        reg.validate()


def test_cycle_rejected():
    reg = StageRegistry()
    reg.register(StageSpec(name="a", deps=("b",)))
    reg.register(StageSpec(name="b", deps=("c",)))
    reg.register(StageSpec(name="c", deps=("a",)))
    with pytest.raises(StageRegistryError, match="cycle"):
        reg.default_order()


def test_topo_order_deterministic_with_registration_tiebreak():
    reg = StageRegistry()
    reg.register(StageSpec(name="z"))
    reg.register(StageSpec(name="m", deps=("z",)))
    reg.register(StageSpec(name="a"))
    # z and a are both ready at step 1: registration order wins, not alpha
    assert reg.default_order() == ["z", "m", "a"]


def test_issue_binding_conflict_rejected():
    reg = StageRegistry()
    reg.register(StageSpec(name="a", issue_types=("shared",)))
    with pytest.raises(StageRegistryError, match="already bound"):
        reg.register(StageSpec(name="b", issue_types=("shared",)))


def test_bind_issue_unknown_stage_rejected():
    with pytest.raises(ValueError, match="unknown stage"):
        register_issue_type("custom_issue_x", "no_such_stage")


# ---------------------------------------------------------------------------
# derived surfaces
# ---------------------------------------------------------------------------

def test_issue_routing_is_registry_view():
    # the issues module exposes the registry's live dict
    assert ISSUE_TO_STAGE is DEFAULT_REGISTRY.issue_to_stage
    assert ISSUE_TO_STAGE["unfused_kernels"] == "fusion"
    assert ISSUE_TO_STAGE["suboptimal_tile_size"] == "gpu_specific"
    # every registered stage owns at least one issue type (skip logic can
    # reach it) and every binding routes to a registered stage
    stages_with_bindings = set(ISSUE_TO_STAGE.values())
    for spec in DEFAULT_REGISTRY:
        assert spec.name in stages_with_bindings, spec.name
    for t, s in ISSUE_TO_STAGE.items():
        assert s in DEFAULT_REGISTRY, (t, s)


def test_registry_planner_order_equals_default_under_equal_severity():
    # issues of equal severity across all nine stages -> the severity-greedy
    # topo sort must reproduce the registry's canonical order exactly
    issues = []
    for spec in DEFAULT_REGISTRY:
        issues.append(Issue(type=spec.issue_types[0], severity=3,
                            description="x"))
    assert plan(issues) == PAPER_ORDER


def test_make_proposer_via_registry():
    from repro.core.proposers import (AutotuneProposer, RewriteProposer,
                                      make_proposer)
    p = make_proposer("algorithmic", None, None)
    assert isinstance(p, RewriteProposer) and p.stage == "algorithmic"
    p = make_proposer("autotuning", None, None)
    assert isinstance(p, AutotuneProposer)
    with pytest.raises(StageRegistryError, match="unknown stage"):
        make_proposer("nope", None, None)


def test_ablation_subsets_preserved_through_registry(tmp_path):
    """stages_enabled filters the registry-derived plan the same way it
    filtered the old hardcoded lists (scheduler + planner paths)."""
    from repro.core.stage_scheduler import StageScheduler
    from repro.ir.cost import CostModel
    from repro.hw.specs import TPU_V5E
    subset = ["gpu_specific", "autotuning"]
    sched = StageScheduler(None, CostModel(TPU_V5E), stages_enabled=subset,
                           use_planner=False)
    issues = [Issue(type="suboptimal_tile_size", severity=3, description=""),
              Issue(type="unfused_kernels", severity=5, description=""),
              Issue(type="missing_autotune", severity=1, description="")]
    assert sched._plan(issues) == ["gpu_specific", "autotuning"]
    sched_all = StageScheduler(None, CostModel(TPU_V5E), use_planner=False)
    assert sched_all._plan(issues) == PAPER_ORDER


# ---------------------------------------------------------------------------
# third-party registration
# ---------------------------------------------------------------------------

def test_third_party_stage_registers_without_touching_core():
    class NullProposer:
        def __init__(self, kb, ctx):
            self.kb, self.ctx = kb, ctx

        def candidates(self, program, issues, trajectory):
            return iter(())

    name = "test_custom_stage"
    try:
        DEFAULT_REGISTRY.register(StageSpec(
            name=name, deps=("autotuning",),
            proposer=lambda kb, ctx: NullProposer(kb, ctx),
            issue_types=("test_custom_issue",),
            doc="test-only stage"))
        # appears at the end of the derived order (depends on autotuning)
        order = DEFAULT_REGISTRY.default_order()
        assert order[-1] == name
        assert order[:-1] == PAPER_ORDER
        # live views see it immediately
        assert DEFAULT_ORDER == PAPER_ORDER + [name]
        assert ISSUE_TO_STAGE["test_custom_issue"] == name
        assert Issue(type="test_custom_issue", severity=2,
                     description="").stage == name
        # proposer resolves through the registry path
        from repro.core.proposers import make_proposer
        assert isinstance(make_proposer(name, None, None), NullProposer)
        # the KB loader's stage whitelist is live too: YAMLs tagged with the
        # custom stage load without code changes
        from repro.kb.loader import STAGES, _norm_stage
        assert name in STAGES
        assert _norm_stage(name) == name
        # the gate passes with the custom stage in place
        assert DEFAULT_REGISTRY.check() == []
    finally:
        DEFAULT_REGISTRY._specs.pop(name, None)
        DEFAULT_REGISTRY._issue_to_stage.pop("test_custom_issue", None)


def test_registry_views_support_plain_list_reads():
    """DEFAULT_ORDER/HARD_DEPS/STAGES are live views; every common list read
    must see current (never empty) content."""
    import pickle
    from repro.kb.loader import STAGES
    assert DEFAULT_ORDER.copy() == PAPER_ORDER
    assert list(reversed(DEFAULT_ORDER)) == PAPER_ORDER[::-1]
    assert DEFAULT_ORDER.count("fusion") == 1
    assert DEFAULT_ORDER + ["x"] == PAPER_ORDER + ["x"]
    assert ["x"] + DEFAULT_ORDER == ["x"] + PAPER_ORDER
    assert DEFAULT_ORDER.index("fusion") == 3
    assert len(STAGES) == 10 and STAGES[0] == "analysis"
    # views pickle as plain snapshot lists (process-pool friendly)
    assert pickle.loads(pickle.dumps(DEFAULT_ORDER)) == PAPER_ORDER


# ---------------------------------------------------------------------------
# CI gate
# ---------------------------------------------------------------------------

def test_check_clean_on_default_registry():
    assert DEFAULT_REGISTRY.check() == []


def test_check_flags_missing_proposer_and_binding():
    reg = StageRegistry()
    reg.register(StageSpec(name="lonely"))
    problems = reg.check()
    assert any("no proposer factory" in p for p in problems)
    assert any("no issue binding" in p for p in problems)


def test_check_cli_exit_codes(capsys):
    from repro.core.stages import main
    assert main(["--check"]) == 0
    out = capsys.readouterr().out
    assert "OK" in out and "topo order" in out
